//! Request traces: who asks for how much, when.
//!
//! A [`RequestTrace`] holds one arrival schedule per client slot — each
//! entry a [`TraceRequest`] with an arrival wave, a target output length,
//! and a per-request SLO. Generators (open-loop Poisson and bursty) are
//! deterministic from the scenario seed via per-client PRNG forks, so a
//! trace-driven run replays bit-exactly like every other experiment;
//! explicit schedules load from a JSON trace file.
//!
//! Arrival times are in *waves* — the coordinator's virtual clock, the
//! same unit [`ChurnEvent::at_wave`](crate::configsys::ChurnEvent) uses —
//! so the live cluster and the analytic simulator consume one trace
//! identically.

use anyhow::{anyhow, Context, Result};

use crate::configsys::{ArrivalProcess, Scenario, TraceConfig, Value};
use crate::util::Rng;

/// One request in a client's arrival schedule.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceRequest {
    /// Wave at which the request arrives (it can be served from the wave
    /// with this index onward).
    pub arrival: u64,
    /// Target output length, tokens.
    pub output_tokens: usize,
    /// Deadline, waves from arrival: the request meets its SLO when it
    /// completes within this many waves.
    pub slo_waves: u64,
}

/// Per-client request arrival schedules (slot-indexed, each sorted by
/// arrival wave).
#[derive(Clone, Debug, Default)]
pub struct RequestTrace {
    pub per_client: Vec<Vec<TraceRequest>>,
}

/// Exponential inter-arrival gap with the given mean (waves).
fn exp_gap(rng: &mut Rng, mean: f64) -> f64 {
    -mean * (1.0 - rng.f64()).ln()
}

impl RequestTrace {
    /// The scenario's trace, resolved: generators run one per-client
    /// stream (forked from the scenario seed) for each of the scenario's
    /// *initial* clients; file traces load their explicit schedules.
    /// Slots beyond the covered set — churn joiners and reserve slots —
    /// stay untracked in the [`RequestTracker`](super::RequestTracker)
    /// (classic closed-loop behavior), so no requests are scheduled for
    /// clients that may never join and nothing is recorded as a miss the
    /// scheduler could not have served. Errors when the scenario has no
    /// trace config or the file is unreadable/malformed.
    pub fn from_scenario(scenario: &Scenario, slots: usize) -> Result<RequestTrace> {
        let cfg = scenario
            .trace
            .as_ref()
            .ok_or_else(|| anyhow!("scenario '{}' has no trace config", scenario.id))?;
        match &cfg.arrival {
            ArrivalProcess::File(path) => {
                let t = RequestTrace::from_file(path)?;
                // A file with more client schedules than the scenario has
                // clients would be silently truncated — the SLO report
                // would cover half the intended workload with no warning.
                if t.per_client.len() > scenario.num_clients {
                    return Err(anyhow!(
                        "trace file '{path}' schedules {} clients but scenario '{}' has \
                         only {} (raise --clients or trim the file)",
                        t.per_client.len(),
                        scenario.id,
                        scenario.num_clients
                    ));
                }
                Ok(t)
            }
            _ => Ok(RequestTrace::generate(cfg, scenario.seed, scenario.num_clients.min(slots))),
        }
    }

    /// Generate `slots` open-loop schedules from `cfg`'s arrival process.
    /// Deterministic: client `i` draws from `fork(i)` of a root stream
    /// seeded `seed ^ 0x57ACE`, so schedules are stable regardless of
    /// sibling consumption (the same discipline the draft servers use).
    ///
    /// Panics if called with [`ArrivalProcess::File`] — file traces load,
    /// they are not generated.
    pub fn generate(cfg: &TraceConfig, seed: u64, slots: usize) -> RequestTrace {
        let mut root = Rng::new(seed ^ 0x57ACE);
        let per_client = (0..slots)
            .map(|i| {
                let mut rng = root.fork(i as u64);
                let mut t = 0.0f64;
                let mut reqs: Vec<TraceRequest> = Vec::with_capacity(cfg.requests_per_client);
                while reqs.len() < cfg.requests_per_client {
                    let burst = match cfg.arrival {
                        ArrivalProcess::Poisson { mean_gap } => {
                            t += exp_gap(&mut rng, mean_gap);
                            1
                        }
                        ArrivalProcess::Bursty { mean_gap, burst } => {
                            t += exp_gap(&mut rng, mean_gap);
                            burst
                        }
                        ArrivalProcess::FlashCrowd { mean_gap, surge, at, width } => {
                            // Inhomogeneous Poisson, stepwise: inside the
                            // surge window the rate multiplies by `surge`,
                            // i.e. the mean gap divides by it.
                            let now = t.floor() as u64;
                            let in_surge = now >= at && now < at.saturating_add(width);
                            let gap = if in_surge { mean_gap / surge.max(1.0) } else { mean_gap };
                            t += exp_gap(&mut rng, gap);
                            1
                        }
                        ArrivalProcess::Diurnal { mean_gap, amplitude, period } => {
                            // Rate 1/mean_gap scaled by the sinusoid at the
                            // current virtual time (validation keeps
                            // amplitude < 1, so the scale stays positive).
                            let phase = 2.0 * std::f64::consts::PI * (t / period);
                            let scale = (1.0 + amplitude * phase.sin()).max(1e-6);
                            t += exp_gap(&mut rng, mean_gap / scale);
                            1
                        }
                        ArrivalProcess::File(_) => {
                            unreachable!("file traces load, they are not generated")
                        }
                    };
                    for _ in 0..burst {
                        if reqs.len() >= cfg.requests_per_client {
                            break;
                        }
                        reqs.push(TraceRequest {
                            arrival: t.floor() as u64,
                            output_tokens: cfg.output_tokens,
                            slo_waves: cfg.slo_waves,
                        });
                    }
                }
                reqs
            })
            .collect();
        RequestTrace { per_client }
    }

    /// Load an explicit trace from a JSON file:
    ///
    /// ```json
    /// {"clients": [
    ///   [{"arrival": 0, "tokens": 24, "slo": 30},
    ///    {"arrival": 12, "tokens": 48, "slo": 60}],
    ///   [{"arrival": 4, "tokens": 24, "slo": 30}]
    /// ]}
    /// ```
    ///
    /// Outer array index = client slot; clients beyond the file's lists
    /// are untracked (they keep the classic closed-loop behavior). Each
    /// client's requests are sorted by arrival on load.
    pub fn from_file(path: &str) -> Result<RequestTrace> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("read trace file {path}"))?;
        RequestTrace::from_json(&text).with_context(|| format!("parse trace file {path}"))
    }

    /// Parse the trace-file JSON (see [`RequestTrace::from_file`]).
    pub fn from_json(text: &str) -> Result<RequestTrace> {
        let v = Value::parse(text).map_err(|e| anyhow!("{e}"))?;
        let clients = v
            .get("clients")
            .and_then(Value::as_array)
            .ok_or_else(|| anyhow!("trace file needs a top-level \"clients\" array"))?;
        let mut per_client = Vec::with_capacity(clients.len());
        for (i, list) in clients.iter().enumerate() {
            let list = list
                .as_array()
                .ok_or_else(|| anyhow!("client {i}: expected an array of requests"))?;
            let mut reqs = Vec::with_capacity(list.len());
            for (j, req) in list.iter().enumerate() {
                let field = |key: &str| -> Result<f64> {
                    req.get(key).and_then(Value::as_f64).ok_or_else(|| {
                        anyhow!("client {i} request {j}: missing numeric field \"{key}\"")
                    })
                };
                let (arrival, tokens, slo) = (field("arrival")?, field("tokens")?, field("slo")?);
                if arrival < 0.0 || tokens < 1.0 || slo < 1.0 {
                    return Err(anyhow!(
                        "client {i} request {j}: arrival ≥ 0, tokens ≥ 1, slo ≥ 1 required"
                    ));
                }
                reqs.push(TraceRequest {
                    arrival: arrival as u64,
                    output_tokens: tokens as usize,
                    slo_waves: slo as u64,
                });
            }
            reqs.sort_by_key(|r| r.arrival);
            per_client.push(reqs);
        }
        Ok(RequestTrace { per_client })
    }

    /// Total requests across all clients.
    pub fn total_requests(&self) -> usize {
        self.per_client.iter().map(Vec::len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(arrival: ArrivalProcess, n: usize) -> TraceConfig {
        TraceConfig { arrival, slo_waves: 30, output_tokens: 24, requests_per_client: n }
    }

    #[test]
    fn generation_is_deterministic_and_per_client_independent() {
        let c = cfg(ArrivalProcess::Poisson { mean_gap: 10.0 }, 16);
        let a = RequestTrace::generate(&c, 7, 4);
        let b = RequestTrace::generate(&c, 7, 4);
        let other_seed = RequestTrace::generate(&c, 8, 4);
        assert_eq!(a.per_client, b.per_client, "same seed ⇒ same trace");
        assert_ne!(a.per_client, other_seed.per_client, "seed must matter");
        assert_eq!(a.per_client.len(), 4);
        assert_eq!(a.total_requests(), 64);
        // Clients draw independent streams.
        assert_ne!(a.per_client[0], a.per_client[1]);
        // Arrivals ascend within each client.
        for reqs in &a.per_client {
            for w in reqs.windows(2) {
                assert!(w[0].arrival <= w[1].arrival);
            }
        }
    }

    #[test]
    fn poisson_gaps_have_roughly_the_configured_mean() {
        let c = cfg(ArrivalProcess::Poisson { mean_gap: 8.0 }, 4000);
        let t = RequestTrace::generate(&c, 3, 1);
        let last = t.per_client[0].last().unwrap().arrival as f64;
        let mean_gap = last / 3999.0;
        assert!((mean_gap - 8.0).abs() < 0.5, "empirical mean gap {mean_gap}");
    }

    #[test]
    fn bursty_arrivals_come_in_bursts() {
        let c = cfg(ArrivalProcess::Bursty { mean_gap: 50.0, burst: 3 }, 9);
        let t = RequestTrace::generate(&c, 5, 1);
        let reqs = &t.per_client[0];
        assert_eq!(reqs.len(), 9);
        // Every burst shares one arrival wave.
        for chunk in reqs.chunks(3) {
            assert!(chunk.iter().all(|r| r.arrival == chunk[0].arrival), "{chunk:?}");
        }
        // Bursts themselves are spread out (mean gap 50 over two gaps ⇒
        // the last burst lands after the first with overwhelming margin).
        assert!(reqs[8].arrival > reqs[0].arrival, "{reqs:?}");
    }

    #[test]
    fn flash_crowd_surges_inside_the_window() {
        // Mean gap 50 outside the window, 2 inside ([100, 150)): the
        // surge window must hold far more arrivals than the equal-width
        // window before it.
        let c = cfg(
            ArrivalProcess::FlashCrowd { mean_gap: 50.0, surge: 25.0, at: 100, width: 50 },
            40,
        );
        let t = RequestTrace::generate(&c, 11, 1);
        let reqs = &t.per_client[0];
        assert_eq!(reqs.len(), 40);
        for w in reqs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        let before = reqs.iter().filter(|r| r.arrival >= 50 && r.arrival < 100).count();
        let inside = reqs.iter().filter(|r| r.arrival >= 100 && r.arrival < 150).count();
        assert!(
            inside > 3 * before.max(1),
            "surge window must dominate: {inside} inside vs {before} before"
        );
        // Determinism (the shared generator discipline).
        let again = RequestTrace::generate(&c, 11, 1);
        assert_eq!(t.per_client, again.per_client);
    }

    #[test]
    fn diurnal_peak_half_outdraws_the_trough_half() {
        // Amplitude 0.9 over a 100-wave period: rate swings 0.1–1.9×.
        // Folding arrivals by phase, the sin-positive half-period must
        // collect well over half of them.
        let c = cfg(
            ArrivalProcess::Diurnal { mean_gap: 10.0, amplitude: 0.9, period: 100.0 },
            400,
        );
        let t = RequestTrace::generate(&c, 13, 1);
        let reqs = &t.per_client[0];
        assert_eq!(reqs.len(), 400);
        let peak = reqs.iter().filter(|r| r.arrival % 100 < 50).count();
        let trough = reqs.len() - peak;
        assert!(peak > 2 * trough, "peak half {peak} vs trough half {trough}");
    }

    #[test]
    fn json_trace_roundtrip_and_errors() {
        let t = RequestTrace::from_json(
            r#"{"clients": [
                 [{"arrival": 12, "tokens": 48, "slo": 60},
                  {"arrival": 0, "tokens": 24, "slo": 30}],
                 []
               ]}"#,
        )
        .unwrap();
        assert_eq!(t.per_client.len(), 2);
        // Sorted by arrival on load.
        assert_eq!(
            t.per_client[0][0],
            TraceRequest { arrival: 0, output_tokens: 24, slo_waves: 30 }
        );
        assert_eq!(t.per_client[0][1].arrival, 12);
        assert!(t.per_client[1].is_empty());
        assert_eq!(t.total_requests(), 2);

        assert!(RequestTrace::from_json("[]").is_err(), "needs a clients object");
        assert!(
            RequestTrace::from_json(r#"{"clients": [[{"arrival": 1}]]}"#).is_err(),
            "missing fields must error"
        );
        assert!(
            RequestTrace::from_json(r#"{"clients": [[{"arrival": 1, "tokens": 0, "slo": 5}]]}"#)
                .is_err(),
            "zero-token requests rejected"
        );
    }

    #[test]
    fn from_scenario_resolves_generators() {
        let s = Scenario::preset("trace").unwrap();
        let t = RequestTrace::from_scenario(&s, s.num_clients).unwrap();
        assert_eq!(t.per_client.len(), 4);
        assert!(t.total_requests() > 0);
        // Slots beyond the initial clients (churn joiners, reserve
        // headroom) get no generated schedule: they stay untracked, so
        // no request can expire against a client that never joined.
        let wide = RequestTrace::from_scenario(&s, 7).unwrap();
        assert_eq!(wide.per_client.len(), 4);
        assert_eq!(wide.per_client, t.per_client, "coverage must not shift the streams");
        let bare = Scenario::preset("smoke").unwrap();
        assert!(RequestTrace::from_scenario(&bare, 2).is_err());
    }
}
