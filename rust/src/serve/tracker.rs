//! Request lifecycle accounting: arrivals → queueing → decode → SLO.
//!
//! The [`RequestTracker`] layers discrete request lifecycles onto the
//! wave stream. It is a pure accounting overlay over the exact same wave
//! observations the scheduler sees, driven identically by the live
//! cluster and the analytic simulator:
//!
//! 1. **Wave start** ([`RequestTracker::sync_wave_start`]) — promote due
//!    arrivals, mark clients with no active request *idle* on the shared
//!    [`RoundCore`] (idle members are granted 0, like a drain, so their
//!    budget water-fills over busy clients — without retiring the
//!    session), and publish each busy client's SLO headroom to the
//!    closed-loop speculation controller when one is installed.
//! 2. **Wave end** ([`RequestTracker::sync_wave_end`]) — attribute the
//!    wave's realized goodput to the active requests: the first token
//!    stamps TTFT, reaching the target output stamps completion, and
//!    leftover tokens spill into the next *arrived* request (continuous
//!    batching; tokens never spill into the future).
//!
//! Every finished request yields a [`RequestRecord`] carrying TTFT /
//! TPOT / E2E (in waves — the stack's virtual time unit) and whether the
//! deadline was met; [`RequestTracker::summary`] reduces them to the
//! p50/p95/p99 report row and the run's *SLO-goodput*: tokens belonging
//! to requests that met their deadline, the serving-side counterpart of
//! the paper's raw goodput.

use std::collections::VecDeque;

use crate::coordinator::RoundCore;
use crate::metrics::sketch::RequestSketch;
use crate::spec::expected_goodput;
use crate::util::stats::p50_p95_p99;

use super::trace::{RequestTrace, TraceRequest};

/// One request's completed (or expired) lifecycle.
#[derive(Clone, Debug)]
pub struct RequestRecord {
    /// Client slot the request belongs to.
    pub client: usize,
    /// Arrival wave.
    pub arrival: u64,
    /// Wave that produced the request's first token (`None` when the
    /// request expired before ever being served).
    pub first_token: Option<u64>,
    /// Wave during which the request completed (for expired requests:
    /// the final wave of the run).
    pub completion: u64,
    /// Tokens attributed to the request (== the target for completed
    /// requests; the partial count for expired ones).
    pub tokens: usize,
    /// The request's deadline, waves from arrival.
    pub slo_waves: u64,
    /// Whether the full target output was produced.
    pub completed: bool,
    /// Whether it completed within `slo_waves` of arrival.
    pub met: bool,
}

impl RequestRecord {
    /// Time to first token, waves (inclusive: a request served in its
    /// arrival wave has TTFT 1 — one wave of service produced the token).
    pub fn ttft_waves(&self) -> f64 {
        match self.first_token {
            Some(w) => (w + 1 - self.arrival) as f64,
            None => (self.completion + 1).saturating_sub(self.arrival) as f64,
        }
    }

    /// End-to-end latency, waves (inclusive, like TTFT).
    pub fn e2e_waves(&self) -> f64 {
        (self.completion + 1 - self.arrival) as f64
    }

    /// Time per output token after the first, waves.
    pub fn tpot_waves(&self) -> f64 {
        let first = self.first_token.unwrap_or(self.completion);
        (self.completion - first) as f64 / self.tokens.saturating_sub(1).max(1) as f64
    }
}

/// The p50/p95/p99 report row of a trace-driven run.
#[derive(Clone, Debug, Default)]
pub struct SloSummary {
    /// Requests that produced their full target output.
    pub completed: u64,
    /// Requests whose deadline passed before they finished.
    pub expired: u64,
    /// Requests still pending (deadline in the future) when the run
    /// ended — excluded from attainment so short runs are not penalized.
    pub censored: u64,
    /// `met / (completed + expired)`; 1.0 when nothing is attributable.
    pub attainment: f64,
    /// (p50, p95, p99) over completed requests, waves.
    pub ttft: (f64, f64, f64),
    pub tpot: (f64, f64, f64),
    pub e2e: (f64, f64, f64),
    /// Σ tokens of deadline-met requests.
    pub slo_goodput_total: f64,
}

/// An in-service request.
#[derive(Clone, Debug)]
struct Active {
    arrival: u64,
    slo_waves: u64,
    /// Absolute deadline wave: completing during wave `deadline − 1` (or
    /// earlier) meets the SLO under the inclusive-latency convention.
    deadline: u64,
    target: usize,
    done: usize,
    first_token: Option<u64>,
}

impl Active {
    fn from_trace(r: TraceRequest) -> Active {
        Active {
            arrival: r.arrival,
            slo_waves: r.slo_waves,
            deadline: r.arrival + r.slo_waves,
            target: r.output_tokens.max(1),
            done: 0,
            first_token: None,
        }
    }
}

/// A suspended in-service request, expressed in slot-relative *ages* so
/// it can be re-based onto another shard's wave clock (shard clocks tick
/// independently; absolute wave numbers do not transfer).
#[derive(Clone, Debug)]
pub struct ActiveExport {
    /// Waves since the request arrived.
    pub age: u64,
    /// Deadline, waves from arrival.
    pub slo_waves: u64,
    /// Target output tokens.
    pub target: usize,
    /// Tokens already produced.
    pub done: usize,
    /// Waves since the first token, when one was produced.
    pub first_token_age: Option<u64>,
}

/// A queued request in handoff form: `arrival_in` waves from "now"
/// (0 ⇒ already arrived and waiting).
#[derive(Clone, Debug)]
pub struct QueuedExport {
    pub arrival_in: u64,
    pub output_tokens: usize,
    pub slo_waves: u64,
}

/// One client's portable request state, produced by
/// [`RequestTracker::export_client`] when a session migrates between
/// shards and consumed by [`RequestTracker::import_client`] on arrival.
/// Unlike [`RequestTracker::untrack`], an export censors nothing — the
/// requests stay live, they just change wave clocks.
#[derive(Clone, Debug, Default)]
pub struct ClientRequestState {
    pub active: Option<ActiveExport>,
    pub queue: Vec<QueuedExport>,
}

impl ClientRequestState {
    /// Work items an *unclaimed* handoff abandons at run end: the
    /// in-flight request plus already-arrived backlog — the same set
    /// [`RequestTracker::untrack`] censors.
    pub fn censorable(&self) -> u64 {
        self.active.is_some() as u64
            + self.queue.iter().filter(|q| q.arrival_in == 0).count() as u64
    }
}

/// Slot-indexed request bookkeeping for one run.
pub struct RequestTracker {
    queues: Vec<VecDeque<TraceRequest>>,
    active: Vec<Option<Active>>,
    /// Slots the trace covers. Untracked slots (e.g. reserve slots beyond
    /// a file trace's lists) keep the classic closed-loop behavior: never
    /// idled, never attributed.
    tracked: Vec<bool>,
    /// Ascending index of tracked slots — the wave-boundary promotion
    /// loop walks this instead of scanning every slot, so per-wave cost
    /// is O(tracked members), not O(slots). Ascending order keeps record
    /// emission order (and thus CSV bytes) identical to the full scan.
    tracked_ids: Vec<usize>,
    busy: Vec<bool>,
    records: Vec<RequestRecord>,
    /// Streaming mode: finished requests fold into this bounded sketch
    /// instead of accruing `records`. `None` ⇒ retained mode (default).
    sketch: Option<RequestSketch>,
    /// Per-slot Σ tokens of deadline-met requests.
    slo_tokens: Vec<f64>,
    censored: u64,
    /// Cumulative deadline-missed requests filed so far (live counter —
    /// the telemetry layer's SLO-breach streak detector reads it at wave
    /// boundaries, so it must be maintained mid-run, not at `finish`).
    missed: u64,
}

impl RequestTracker {
    /// A tracker over `slots` client slots. Slots beyond the trace's
    /// per-client lists are untracked.
    pub fn new(trace: RequestTrace, slots: usize) -> RequestTracker {
        let covered = trace.per_client.len().min(slots);
        let mut queues: Vec<VecDeque<TraceRequest>> =
            trace.per_client.into_iter().take(slots).map(VecDeque::from).collect();
        queues.resize_with(slots, VecDeque::new);
        RequestTracker {
            queues,
            active: (0..slots).map(|_| None).collect(),
            tracked: (0..slots).map(|i| i < covered).collect(),
            tracked_ids: (0..covered).collect(),
            busy: vec![true; slots],
            records: Vec::new(),
            sketch: None,
            slo_tokens: vec![0.0; slots],
            censored: 0,
            missed: 0,
        }
    }

    /// Switch to streaming aggregation: finished requests fold into a
    /// bounded [`RequestSketch`] (any already-retained records are folded
    /// in first) so soak-length runs hold O(clients) tracker memory.
    /// Retained mode keeps every [`RequestRecord`] and stays the default
    /// — its CSV output is byte-identical to prior releases.
    pub fn stream(&mut self) {
        let mut sk = self.sketch.take().unwrap_or_default();
        for r in &self.records {
            sk.push(r);
        }
        self.records.clear();
        self.sketch = Some(sk);
    }

    /// Restrict tracking to `members` (ascending slot ids): slots the
    /// tracker covers but this shard does not serve revert to untracked
    /// — *without* censoring, because their requests belong to another
    /// shard's tracker partition, not to an ended session. Each shard of
    /// a sharded run builds the full trace and then retains only its own
    /// members, so every request is owned by exactly one shard.
    pub fn retain_members(&mut self, members: &[usize]) {
        let old = std::mem::take(&mut self.tracked_ids);
        for id in old {
            if members.binary_search(&id).is_ok() {
                self.tracked_ids.push(id);
            } else {
                self.tracked[id] = false;
                self.busy[id] = true;
                self.active[id] = None;
                self.queues[id].clear();
            }
        }
    }

    /// Suspend a migrating client's request state for transfer to
    /// another shard's tracker. Ages are relative to `now` (this shard's
    /// current wave) so [`RequestTracker::import_client`] can re-base
    /// them onto the destination clock. Returns `None` for untracked
    /// slots. Nothing is censored — the requests stay live in the
    /// returned state.
    pub fn export_client(&mut self, client: usize, now: u64) -> Option<ClientRequestState> {
        if !self.tracked[client] {
            return None;
        }
        self.tracked[client] = false;
        self.busy[client] = true;
        if let Ok(pos) = self.tracked_ids.binary_search(&client) {
            self.tracked_ids.remove(pos);
        }
        let active = self.active[client].take().map(|a| ActiveExport {
            age: now.saturating_sub(a.arrival),
            slo_waves: a.slo_waves,
            target: a.target,
            done: a.done,
            first_token_age: a.first_token.map(|w| now.saturating_sub(w)),
        });
        let queue = self.queues[client]
            .drain(..)
            .map(|r| QueuedExport {
                arrival_in: r.arrival.saturating_sub(now),
                output_tokens: r.output_tokens,
                slo_waves: r.slo_waves,
            })
            .collect();
        Some(ClientRequestState { active, queue })
    }

    /// Adopt a migrated client's request state, re-basing its ages onto
    /// this tracker's clock (`now`). Arrival waves older than `now` clamp
    /// to 0 — a young destination clock cannot represent a request older
    /// than itself, which only ever *loosens* an already-blown deadline.
    pub fn import_client(&mut self, client: usize, state: ClientRequestState, now: u64) {
        self.tracked[client] = true;
        self.busy[client] = true; // refreshed at the next begin_wave
        if let Err(pos) = self.tracked_ids.binary_search(&client) {
            self.tracked_ids.insert(pos, client);
        }
        self.active[client] = state.active.map(|a| {
            let arrival = now.saturating_sub(a.age);
            Active {
                arrival,
                slo_waves: a.slo_waves,
                deadline: arrival + a.slo_waves,
                target: a.target.max(1),
                done: a.done,
                first_token: a.first_token_age.map(|ft| now.saturating_sub(ft)),
            }
        });
        self.queues[client] = state
            .queue
            .into_iter()
            .map(|q| TraceRequest {
                arrival: now + q.arrival_in,
                output_tokens: q.output_tokens,
                slo_waves: q.slo_waves,
            })
            .collect();
    }

    /// Whether the slot has an active (or untracked ⇒ perpetual) request
    /// as of the last [`RequestTracker::begin_wave`].
    pub fn is_busy(&self, client: usize) -> bool {
        self.busy[client]
    }

    /// Promote due arrivals and refresh the busy mask for wave `wave`.
    /// Walks only tracked slots (untracked slots are pinned busy by
    /// construction, [`RequestTracker::untrack`], and
    /// [`RequestTracker::retain_members`]), so the per-wave cost is
    /// O(tracked members) regardless of the slot-universe size.
    pub fn begin_wave(&mut self, wave: u64) {
        for idx in 0..self.tracked_ids.len() {
            let i = self.tracked_ids[idx];
            if self.active[i].is_none() && self.head_due(i, wave) {
                let req = self.queues[i].pop_front().expect("due head");
                self.active[i] = Some(Active::from_trace(req));
            }
            self.busy[i] = self.active[i].is_some();
        }
    }

    /// Whether the client's next queued request has already arrived.
    fn head_due(&self, client: usize, wave: u64) -> bool {
        self.queues[client].front().is_some_and(|h| h.arrival <= wave)
    }

    /// Stop tracking a slot (its session retired at wave `wave`): the
    /// in-flight request and any already-arrived backlog are censored —
    /// a departed user's unserved arrivals are not scheduler misses —
    /// while requests that had not yet arrived are dropped outright
    /// (they were never part of the served workload, matching the
    /// never-arrived rule [`RequestTracker::finish`] applies to
    /// survivors). The slot reverts to untracked (never-idle) behavior
    /// so a churned-out member cannot keep accruing phantom SLO
    /// failures.
    pub fn untrack(&mut self, client: usize, wave: u64) {
        if !self.tracked[client] {
            return;
        }
        self.tracked[client] = false;
        self.busy[client] = true;
        if let Ok(pos) = self.tracked_ids.binary_search(&client) {
            self.tracked_ids.remove(pos);
        }
        if self.active[client].take().is_some() {
            self.censored += 1;
        }
        let arrived = self.queues[client].iter().filter(|r| r.arrival <= wave).count();
        self.censored += arrived as u64;
        self.queues[client].clear();
    }

    /// Attribute one client's realized wave goodput to its requests.
    /// Leftover tokens spill into the next already-arrived request;
    /// tokens with no arrived request to serve are dropped (an idle
    /// client's correction token belongs to nobody).
    pub fn observe(&mut self, wave: u64, client: usize, goodput: usize) {
        if !self.tracked[client] {
            return;
        }
        let mut tokens = goodput;
        while tokens > 0 {
            if self.active[client].is_none() {
                if !self.head_due(client, wave) {
                    break;
                }
                let req = self.queues[client].pop_front().expect("due head");
                self.active[client] = Some(Active::from_trace(req));
            }
            let a = self.active[client].as_mut().expect("active request");
            if a.first_token.is_none() {
                a.first_token = Some(wave);
            }
            let take = tokens.min(a.target - a.done);
            a.done += take;
            tokens -= take;
            if a.done >= a.target {
                let a = self.active[client].take().expect("completing request");
                // Inclusive latency: completing during wave w costs
                // w + 1 − arrival waves.
                let met = wave + 1 - a.arrival <= a.slo_waves;
                if met {
                    self.slo_tokens[client] += a.target as f64;
                }
                self.record(RequestRecord {
                    client,
                    arrival: a.arrival,
                    first_token: a.first_token,
                    completion: wave,
                    tokens: a.target,
                    slo_waves: a.slo_waves,
                    completed: true,
                    met,
                });
            }
        }
    }

    /// SLO headroom of the client's work queue: how far its expected
    /// service rate exceeds the rate its deadlines require, as a
    /// fraction (`0` = exactly on track, `> 0` = ahead, `< 0` = behind
    /// or past due). The constraint is EDF-style over the active request
    /// *plus* the arrived backlog — for each work item `k`, the
    /// cumulative tokens through `k` must land before `k`'s deadline —
    /// and the binding (minimum) slack is reported, so a backlogged
    /// client with tight deadlines reads behind while one queueing loose
    /// requests can still be throttled safely. Idle (and untracked)
    /// clients report `+∞`: no deadline pressure.
    pub fn headroom(&self, client: usize, wave: u64, expected_rate: f64) -> f64 {
        if !self.tracked[client] {
            return f64::INFINITY;
        }
        let mut need = 0usize;
        let mut worst = f64::INFINITY;
        let mut constrain = |remaining: usize, deadline: u64| -> bool {
            need += remaining;
            let left = deadline.saturating_sub(wave);
            if left == 0 {
                worst = -1.0;
                return false;
            }
            let required = need as f64 / left as f64;
            worst = worst.min(expected_rate / required.max(1e-9) - 1.0);
            true
        };
        if let Some(a) = &self.active[client] {
            if !constrain(a.target - a.done, a.deadline) {
                return -1.0;
            }
        }
        for r in self.queues[client].iter().take_while(|r| r.arrival <= wave) {
            if !constrain(r.output_tokens.max(1), r.arrival + r.slo_waves) {
                return -1.0;
            }
        }
        if worst.is_infinite() {
            return f64::INFINITY; // nothing arrived: idle
        }
        worst.clamp(-1.0, 1e6)
    }

    /// Wave-boundary sync into the shared core: promote arrivals, set the
    /// idle mask over `members`, and (when the core runs the closed-loop
    /// controller) publish each member's SLO headroom evaluated at its
    /// learned acceptance rate and current speculation cap.
    pub fn sync_wave_start(&mut self, core: &mut RoundCore, wave: u64, members: &[usize]) {
        self.begin_wave(wave);
        for &i in members {
            self.publish_member(core, wave, i);
        }
    }

    /// [`RequestTracker::sync_wave_start`] over the tracker's own tracked
    /// set — the natural drive for a shard whose tracker was already
    /// restricted with [`RequestTracker::retain_members`]: the member
    /// list and the tracked set coincide, so no caller-side member vector
    /// is needed and the cost is O(tracked members).
    pub fn sync_wave_start_tracked(&mut self, core: &mut RoundCore, wave: u64) {
        self.begin_wave(wave);
        for idx in 0..self.tracked_ids.len() {
            let i = self.tracked_ids[idx];
            self.publish_member(core, wave, i);
        }
    }

    /// Per-member half of the wave-start sync: idle mask plus, under the
    /// closed-loop controller, the SLO-headroom signal.
    fn publish_member(&self, core: &mut RoundCore, wave: u64, i: usize) {
        core.set_idle(i, !self.is_busy(i));
        if core.turbo_enabled() {
            let expected = expected_goodput(core.estimators.alpha_hat[i], core.turbo_cap(i));
            let h = self.headroom(i, wave, expected);
            core.set_slo_headroom(i, h);
        }
    }

    /// Post-wave attribution of `(client, goodput)` pairs.
    pub fn sync_wave_end(&mut self, wave: u64, outcomes: &[(usize, usize)]) {
        for &(client, goodput) in outcomes {
            self.observe(wave, client, goodput);
        }
    }

    /// Close the books at the end of the run (`final_wave` = one past the
    /// last processed wave): requests whose deadline already passed are
    /// recorded as expired misses; pending requests whose deadline is
    /// still in the future are censored (dropped from attainment).
    pub fn finish(&mut self, final_wave: u64) {
        for client in 0..self.queues.len() {
            if let Some(a) = self.active[client].take() {
                if a.deadline <= final_wave {
                    self.record(RequestRecord {
                        client,
                        arrival: a.arrival,
                        first_token: a.first_token,
                        completion: final_wave.max(1) - 1,
                        tokens: a.done,
                        slo_waves: a.slo_waves,
                        completed: false,
                        met: false,
                    });
                } else {
                    self.censored += 1;
                }
            }
            while let Some(head) = self.queues[client].pop_front() {
                if head.arrival >= final_wave {
                    // Never arrived within the run: not attributable.
                    continue;
                }
                if head.arrival + head.slo_waves <= final_wave {
                    self.record(RequestRecord {
                        client,
                        arrival: head.arrival,
                        first_token: None,
                        completion: final_wave.max(1) - 1,
                        tokens: 0,
                        slo_waves: head.slo_waves,
                        completed: false,
                        met: false,
                    });
                } else {
                    self.censored += 1;
                }
            }
        }
    }

    /// File a finished/expired request: retained mode accrues the record,
    /// streaming mode folds it into the bounded sketch.
    fn record(&mut self, rec: RequestRecord) {
        if !rec.met {
            self.missed += 1;
        }
        match &mut self.sketch {
            Some(sk) => sk.push(&rec),
            None => self.records.push(rec),
        }
    }

    /// Cumulative deadline-missed requests filed so far (completions
    /// past deadline plus, after [`RequestTracker::finish`], end-of-run
    /// expirations). Monotone — suitable for a breach-streak detector.
    pub fn slo_missed(&self) -> u64 {
        self.missed
    }

    /// All finished/expired request records so far, arrival order within
    /// each client. Empty in streaming mode (records are folded into the
    /// sketch as they finish).
    pub fn records(&self) -> &[RequestRecord] {
        &self.records
    }

    /// Consume the tracker, yielding its records, per-client SLO-goodput
    /// totals, the censored-request count, and — in streaming mode — the
    /// bounded request sketch (all handed to the recorder).
    pub fn into_report(self) -> (Vec<RequestRecord>, Vec<f64>, u64, Option<RequestSketch>) {
        (self.records, self.slo_tokens, self.censored, self.sketch)
    }

    /// Per-client Σ tokens of deadline-met requests.
    pub fn slo_goodput(&self) -> &[f64] {
        &self.slo_tokens
    }

    /// Reduce the records (or, in streaming mode, the sketch) to the
    /// p50/p95/p99 report row. See [`summarize_requests`] for the
    /// free-standing form recorders use.
    pub fn summary(&self) -> SloSummary {
        match &self.sketch {
            Some(sk) => sk.summary(self.censored),
            None => summarize_requests(&self.records, self.censored),
        }
    }
}

/// Reduce request records to the standard SLO report row (percentiles
/// over completed requests; attainment over completed + expired).
pub fn summarize_requests(records: &[RequestRecord], censored: u64) -> SloSummary {
    let done: Vec<&RequestRecord> = records.iter().filter(|r| r.completed).collect();
    let expired = (records.len() - done.len()) as u64;
    let met = records.iter().filter(|r| r.met).count() as u64;
    let attributable = done.len() as u64 + expired;
    let ttft: Vec<f64> = done.iter().map(|r| r.ttft_waves()).collect();
    let tpot: Vec<f64> = done.iter().map(|r| r.tpot_waves()).collect();
    let e2e: Vec<f64> = done.iter().map(|r| r.e2e_waves()).collect();
    SloSummary {
        completed: done.len() as u64,
        expired,
        censored,
        attainment: if attributable == 0 { 1.0 } else { met as f64 / attributable as f64 },
        ttft: p50_p95_p99(&ttft),
        tpot: p50_p95_p99(&tpot),
        e2e: p50_p95_p99(&e2e),
        slo_goodput_total: records.iter().filter(|r| r.met).map(|r| r.tokens as f64).sum(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(reqs: Vec<Vec<(u64, usize, u64)>>) -> RequestTrace {
        RequestTrace {
            per_client: reqs
                .into_iter()
                .map(|c| {
                    c.into_iter()
                        .map(|(arrival, output_tokens, slo_waves)| TraceRequest {
                            arrival,
                            output_tokens,
                            slo_waves,
                        })
                        .collect()
                })
                .collect(),
        }
    }

    #[test]
    fn lifecycle_ttft_e2e_and_slo() {
        // One client, one request: 6 tokens arriving at wave 2, SLO 4.
        let mut t = RequestTracker::new(trace(vec![vec![(2, 6, 4)]]), 1);
        t.begin_wave(0);
        assert!(!t.is_busy(0), "nothing arrived yet");
        t.observe(0, 0, 3); // idle tokens: dropped
        t.begin_wave(2);
        assert!(t.is_busy(0));
        t.observe(2, 0, 3); // first 3 tokens
        t.observe(3, 0, 3); // completes during wave 3
        t.finish(10);
        let recs = t.records();
        assert_eq!(recs.len(), 1);
        let r = &recs[0];
        assert!(r.completed && r.met);
        assert_eq!(r.first_token, Some(2));
        assert_eq!(r.completion, 3);
        assert!((r.ttft_waves() - 1.0).abs() < 1e-12);
        assert!((r.e2e_waves() - 2.0).abs() < 1e-12);
        assert!((r.tpot_waves() - (1.0 / 5.0)).abs() < 1e-12);
        assert_eq!(t.slo_goodput()[0], 6.0);
        let s = t.summary();
        assert_eq!((s.completed, s.expired, s.censored), (1, 0, 0));
        assert!((s.attainment - 1.0).abs() < 1e-12);
        assert!((s.slo_goodput_total - 6.0).abs() < 1e-12);
    }

    #[test]
    fn missed_deadline_keeps_tokens_out_of_slo_goodput() {
        // 8 tokens, SLO 2 waves, served 2 tokens/wave ⇒ completes at wave
        // 3 (e2e 4 > 2): raw tokens flow, SLO-goodput stays 0.
        let mut t = RequestTracker::new(trace(vec![vec![(0, 8, 2)]]), 1);
        for wave in 0..4 {
            t.begin_wave(wave);
            t.observe(wave, 0, 2);
        }
        t.finish(4);
        let r = &t.records()[0];
        assert!(r.completed && !r.met);
        assert_eq!(r.tokens, 8);
        assert_eq!(t.slo_goodput()[0], 0.0);
        let s = t.summary();
        assert!((s.attainment - 0.0).abs() < 1e-12);
        assert!((s.slo_goodput_total - 0.0).abs() < 1e-12);
    }

    #[test]
    fn spillover_feeds_the_next_arrived_request_only() {
        // Two 2-token requests, the second arriving at wave 5. A 6-token
        // wave at wave 0 completes the first but must NOT pre-serve the
        // second; a 6-token wave at 5 completes it with spillover intact.
        let mut t = RequestTracker::new(trace(vec![vec![(0, 2, 10), (5, 2, 10)]]), 1);
        t.begin_wave(0);
        t.observe(0, 0, 6);
        assert_eq!(t.records().len(), 1, "future requests cannot be served");
        t.begin_wave(5);
        t.observe(5, 0, 6);
        assert_eq!(t.records().len(), 2);
        assert_eq!(t.records()[1].first_token, Some(5), "spillover stamps TTFT");
        // Back-to-back arrivals do chain within one wave.
        let mut t = RequestTracker::new(trace(vec![vec![(0, 2, 10), (0, 2, 10)]]), 1);
        t.begin_wave(0);
        t.observe(0, 0, 5);
        assert_eq!(t.records().len(), 2, "burst chains through spillover");
    }

    #[test]
    fn finish_separates_expired_from_censored() {
        // Request A expired (deadline 4 < final 10); request B pending
        // with a future deadline (censored); request C never arrived.
        let schedule = trace(vec![vec![(0, 4, 4)], vec![(8, 4, 40)], vec![(30, 4, 5)]]);
        let mut t = RequestTracker::new(schedule, 3);
        t.begin_wave(8);
        t.observe(8, 1, 1);
        t.finish(10);
        let s = t.summary();
        assert_eq!((s.completed, s.expired, s.censored), (0, 1, 1));
        assert!((s.attainment - 0.0).abs() < 1e-12);
        let expired = &t.records()[0];
        assert_eq!(expired.client, 0);
        assert!(!expired.completed && expired.first_token.is_none());
    }

    #[test]
    fn untracked_slots_stay_busy_and_unattributed() {
        let mut t = RequestTracker::new(trace(vec![vec![(0, 2, 5)]]), 3);
        t.begin_wave(0);
        assert!(t.is_busy(0));
        assert!(t.is_busy(1) && t.is_busy(2), "untracked ⇒ closed loop ⇒ busy");
        t.observe(0, 2, 9);
        t.finish(5);
        assert!(t.records().iter().all(|r| r.client == 0));
        assert_eq!(t.headroom(2, 0, 1.0), f64::INFINITY);
    }

    #[test]
    fn untrack_censors_a_retired_sessions_leftovers() {
        // Client 0 departs at wave 5 with one request active, one
        // arrived-but-queued, and one that would only arrive at wave 60:
        // the first two are censored, the never-arrived one is dropped
        // (same rule `finish` applies to survivors), and none of them
        // may surface as scheduler misses.
        let mut t = RequestTracker::new(trace(vec![vec![(0, 8, 4), (2, 8, 4), (60, 8, 4)]]), 1);
        t.begin_wave(0);
        t.observe(0, 0, 2); // partially served
        t.untrack(0, 5);
        assert!(t.is_busy(0), "untracked slots revert to closed-loop busy");
        t.begin_wave(5);
        t.observe(5, 0, 50); // post-departure tokens: unattributed
        t.finish(100);
        let s = t.summary();
        assert_eq!((s.completed, s.expired), (0, 0), "no phantom misses");
        assert_eq!(s.censored, 2, "active + arrived backlog censored, future dropped");
        assert!((s.attainment - 1.0).abs() < 1e-12, "nothing attributable");
        assert!(t.records().is_empty());
        // Idempotent.
        t.untrack(0, 5);
        assert_eq!(t.summary().censored, 2);
        assert_eq!(t.headroom(0, 5, 1.0), f64::INFINITY);
    }

    #[test]
    fn headroom_signs_match_the_deadline_math() {
        // 10 tokens due in 10 waves ⇒ required rate 1.0.
        let mut t = RequestTracker::new(trace(vec![vec![(0, 10, 10)]]), 1);
        t.begin_wave(0);
        assert!((t.headroom(0, 0, 2.0) - 1.0).abs() < 1e-9, "2× the required rate");
        assert!((t.headroom(0, 0, 0.5) - (-0.5)).abs() < 1e-9, "half the required rate");
        // Past due: hard behind.
        assert!((t.headroom(0, 10, 9.0) - (-1.0)).abs() < 1e-12);
        // Idle: no pressure.
        let t2 = RequestTracker::new(trace(vec![vec![(50, 2, 5)]]), 1);
        assert_eq!(t2.headroom(0, 0, 1.0), f64::INFINITY);
    }

    #[test]
    fn headroom_is_edf_over_the_arrived_backlog() {
        // Active: 10 tokens due in 20 waves (loose). Queued, arrived: 10
        // more due in 10 waves ⇒ the *cumulative* constraint 20 tokens /
        // 10 waves = 2.0 binds, not the active request's 0.5.
        let mut t = RequestTracker::new(trace(vec![vec![(0, 10, 20), (0, 10, 10)]]), 1);
        t.begin_wave(0);
        assert!((t.headroom(0, 0, 2.0) - 0.0).abs() < 1e-9, "cumulative EDF slack");
        // A backlog of *loose* requests leaves positive headroom (the
        // client is safely throttleable despite being busy).
        let mut t = RequestTracker::new(trace(vec![vec![(0, 10, 20), (0, 10, 100)]]), 1);
        t.begin_wave(0);
        assert!(t.headroom(0, 0, 2.0) > 1.0, "loose backlog stays throttleable");
        // Future requests never constrain (they have not arrived).
        let mut t = RequestTracker::new(trace(vec![vec![(0, 10, 20), (90, 10, 2)]]), 1);
        t.begin_wave(0);
        assert!(t.headroom(0, 0, 2.0) > 1.0);
        // A past-due queued request is hard behind.
        let mut t = RequestTracker::new(trace(vec![vec![(0, 10, 20), (0, 10, 3)]]), 1);
        t.begin_wave(3);
        assert!((t.headroom(0, 3, 9.0) - (-1.0)).abs() < 1e-12);
    }

    #[test]
    fn summary_percentiles_over_completed_requests() {
        let mut t = RequestTracker::new(
            trace(vec![vec![(0, 2, 40), (10, 2, 40), (20, 2, 40)]]),
            1,
        );
        for (wave, g) in [(0u64, 2usize), (10, 2), (20, 2)] {
            t.begin_wave(wave);
            t.observe(wave, 0, g);
        }
        t.finish(30);
        let s = t.summary();
        assert_eq!(s.completed, 3);
        // Every request completed in exactly one wave: all latencies 1.
        assert!((s.e2e.0 - 1.0).abs() < 1e-12);
        assert!((s.e2e.2 - 1.0).abs() < 1e-12);
        assert!((s.ttft.1 - 1.0).abs() < 1e-12);
        assert!((s.attainment - 1.0).abs() < 1e-12);
        assert!((s.slo_goodput_total - 6.0).abs() < 1e-12);
    }

    #[test]
    fn retain_members_partitions_without_censoring() {
        // A 4-slot trace split across two "shards": {0, 2} and {1, 3}.
        // Each partition serves only its own clients; nothing is
        // censored and the union of the partitions covers every request.
        let full = || {
            trace(vec![
                vec![(0, 2, 10)],
                vec![(0, 2, 10)],
                vec![(1, 2, 10)],
                vec![(1, 2, 10)],
            ])
        };
        let mut a = RequestTracker::new(full(), 4);
        a.retain_members(&[0, 2]);
        let mut b = RequestTracker::new(full(), 4);
        b.retain_members(&[1, 3]);
        for wave in 0..3 {
            a.begin_wave(wave);
            b.begin_wave(wave);
            for c in [0usize, 2] {
                a.observe(wave, c, 1);
            }
            for c in [1usize, 3] {
                b.observe(wave, c, 1);
            }
        }
        a.finish(3);
        b.finish(3);
        let (sa, sb) = (a.summary(), b.summary());
        assert_eq!((sa.completed, sa.censored), (2, 0));
        assert_eq!((sb.completed, sb.censored), (2, 0));
        assert!(a.records().iter().all(|r| r.client % 2 == 0));
        assert!(b.records().iter().all(|r| r.client % 2 == 1));
        // Dropped slots revert to untracked (closed-loop busy) behavior.
        assert!(a.is_busy(1) && a.is_busy(3));
        assert_eq!(a.headroom(1, 0, 1.0), f64::INFINITY);
    }

    #[test]
    fn export_import_rebases_a_request_across_wave_clocks() {
        // Client 0: 6-token request arriving at wave 2, SLO 8. Serve 2
        // tokens on the source shard (first token at wave 2), migrate at
        // wave 4, then finish on a destination shard whose clock reads 9.
        let mut src = RequestTracker::new(trace(vec![vec![(2, 6, 8), (20, 2, 5)]]), 1);
        src.begin_wave(2);
        src.observe(2, 0, 2);
        let state = src.export_client(0, 4).expect("tracked slot exports");
        assert_eq!(src.summary().censored, 0, "handoff censors nothing");
        assert!(src.is_busy(0), "exported slot reverts to untracked busy");
        let act = state.active.as_ref().expect("in-flight request travels");
        assert_eq!((act.age, act.done, act.first_token_age), (2, 2, Some(2)));
        assert_eq!(state.queue[0].arrival_in, 16);
        assert_eq!(state.censorable(), 1, "active only; future backlog drops");

        let mut dst = RequestTracker::new(trace(vec![vec![]]), 1);
        dst.import_client(0, state, 9);
        dst.begin_wave(9);
        assert!(dst.is_busy(0));
        dst.observe(9, 0, 4); // remaining 4 tokens
        let r = &dst.records()[0];
        // Re-based arrival 9 − 2 = 7; completion at 9 ⇒ e2e 3 ≤ SLO 8.
        assert_eq!((r.arrival, r.completion), (7, 9));
        assert_eq!(r.first_token, Some(7));
        assert!(r.completed && r.met);
        // The future request re-based onto the new clock: due at 9 + 16.
        dst.begin_wave(25);
        assert!(dst.is_busy(0), "queued request follows the migration");
    }

    #[test]
    fn streaming_summary_matches_retained() {
        let schedule = || {
            trace(vec![
                vec![(0, 2, 10), (4, 3, 2), (9, 2, 40)],
                vec![(1, 4, 6), (50, 2, 5)],
            ])
        };
        let drive = |t: &mut RequestTracker| {
            for wave in 0..12 {
                t.begin_wave(wave);
                t.observe(wave, 0, 1);
                t.observe(wave, 1, 1);
            }
            t.finish(12);
        };
        let mut retained = RequestTracker::new(schedule(), 2);
        drive(&mut retained);
        let mut streaming = RequestTracker::new(schedule(), 2);
        streaming.stream();
        drive(&mut streaming);
        assert!(streaming.records().is_empty(), "streaming retains no records");
        let (r, s) = (retained.summary(), streaming.summary());
        assert_eq!((r.completed, r.expired, r.censored), (s.completed, s.expired, s.censored));
        assert!((r.attainment - s.attainment).abs() < 1e-12);
        assert!((r.slo_goodput_total - s.slo_goodput_total).abs() < 1e-12);
        // Few requests ⇒ the reservoirs are exact ⇒ identical percentiles.
        assert_eq!(r.ttft, s.ttft);
        assert_eq!(r.tpot, s.tpot);
        assert_eq!(r.e2e, s.e2e);
        assert_eq!(retained.slo_goodput(), streaming.slo_goodput());
        let (_, _, _, sketch) = streaming.into_report();
        assert!(sketch.is_some(), "streaming report carries the sketch");
    }
}
