//! Analytic round simulator.
//!
//! Replaces model execution with the acceptance process itself: client i
//! has a *true* time-varying acceptance rate α_i(t) (per-domain base rate,
//! Markov domain switching), per-token acceptance indicators are drawn
//! around it, and rejection sampling runs on those indicators. Everything
//! above the engines — estimators, gradient scheduler, baselines, budget
//! accounting, metrics — executes through the *same*
//! [`RoundCore`](crate::coordinator::RoundCore) as the live coordinator,
//! so convergence results transfer and the simulator cannot drift from the
//! serving stack.
//!
//! Used by the Fig 4 full grid (600 iterations × 3 policies × 2 families ×
//! {4, 8} clients), the β-sweep validating Theorem 1, and the ablations.
//!
//! Three coordinator disciplines are modeled: `step()` is one sync barrier
//! round, `step_wave()` is one async wave under a stylized virtual-time
//! model (per-client RTT from the scenario links, per-token draft compute,
//! fixed verify cost), and [`run_sharded`] drives one restricted simulator
//! per verification shard under the pool controller's hierarchical budget
//! split — the analytic counterpart of
//! [`run_pool`](crate::coordinator::run_pool).

use crate::chaos::FaultOp;
use crate::configsys::{
    ChurnEvent, ChurnKind, ClientSpec, CoordMode, Policy, Scenario, SpecShape,
};
use crate::coordinator::{RoundCore, WaveObs};
use crate::metrics::recorder::{FaultRecord, MembershipEvent, Recorder};
use crate::net::link::{draft_msg_bytes, verdict_msg_bytes, Link};
use crate::obs::ObsHub;
use crate::sched::baselines::Allocator;
use crate::sched::gradient::split_budget_by_members;
use crate::sched::Estimators;
use crate::metrics::sketch::RequestSketch;
use crate::serve::{summarize_requests, RequestTrace, RequestTracker, SloSummary};
use crate::spec::tree::{adaptive_profile, DraftTree};
use crate::util::Rng;
use crate::workload::domains::DOMAINS;

/// Base acceptance rate per domain: regular templates are easy for a draft
/// model to imitate, the long-tail domain is not (matches the measured
/// spread of the trained zoo; see EXPERIMENTS.md).
pub fn domain_alpha(domain: &str) -> f64 {
    match domain {
        "alpaca" => 0.85,
        "prompts" => 0.80,
        "cnn" => 0.70,
        "orca" => 0.65,
        "arena" => 0.75,
        "gsm8k" => 0.55,
        "spider" => 0.80,
        "hle" => 0.25,
        _ => 0.5,
    }
}

/// Draft-model quality multiplier (bigger drafts track the target better).
pub fn model_quality(model: &str) -> f64 {
    match model {
        m if m.contains("nano") => 0.65,
        m if m.contains("17b") || m.contains("3b") => 1.1,
        m if m.contains("06b") || m.contains("1b") => 0.9,
        _ => 1.0,
    }
}

/// One simulated client.
#[derive(Clone, Debug)]
pub struct SimClient {
    pub primary_domain: &'static str,
    pub current_domain: &'static str,
    pub quality: f64,
    pub stickiness: f64,
    /// Remaining tokens in the current request.
    pub remaining: usize,
    pub max_new_tokens: usize,
}

impl SimClient {
    /// True per-token acceptance probability right now.
    pub fn true_alpha(&self) -> f64 {
        (domain_alpha(self.current_domain) * self.quality).clamp(0.02, 0.98)
    }
}

/// Simulator configuration (derived from a scenario).
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub capacity: usize,
    pub max_draft: usize,
    pub rounds: u64,
    pub seed: u64,
    /// Std-dev of per-token indicator noise around α (ratio spread).
    pub indicator_noise: f64,
    /// Coordinator discipline to model (sync barrier vs async waves).
    pub mode: CoordMode,
    /// Async batching window, seconds of virtual time.
    pub batch_window_s: f64,
    /// Wave-fill threshold (`0` = all clients).
    pub min_wave_fill: usize,
    /// Virtual-time cost of one batched verify.
    pub verify_s: f64,
    /// Virtual-time draft compute per speculated token.
    pub draft_token_s: f64,
    /// Speculation topology (the live stack's `Scenario::spec_shape`).
    pub spec_shape: SpecShape,
    /// Engine rows available per client (the artifact K): trees are
    /// clamped so `nodes + leaves ≤ verify_rows`, exactly like the live
    /// batcher's phantom-row constraint.
    pub verify_rows: usize,
}

impl SimConfig {
    pub fn from_scenario(s: &Scenario) -> SimConfig {
        SimConfig {
            capacity: s.capacity,
            max_draft: s.max_draft,
            rounds: s.rounds,
            seed: s.seed,
            indicator_noise: 0.15,
            mode: s.coord_mode,
            batch_window_s: s.batch_window_us as f64 * 1e-6,
            min_wave_fill: s.effective_wave_fill(),
            verify_s: 2e-3,
            draft_token_s: 2e-4,
            spec_shape: s.spec_shape,
            // The mock/XLA verify artifacts carry K = 32 rows.
            verify_rows: 32,
        }
    }
}

pub struct AnalyticSim {
    pub cfg: SimConfig,
    pub clients: Vec<SimClient>,
    /// The shared wave-processing core — the same estimator / scheduler /
    /// accounting / record-emission code the live coordinator runs.
    pub core: RoundCore,
    rng: Rng,
    /// Mirror of each client's current (outstanding) allocation — what the
    /// client would draft next wave.
    alloc: Vec<usize>,
    /// Clients this simulator instance drives (all of them outside sharded
    /// mode; one shard's subset under [`run_sharded`]). Always ascending.
    members: Vec<usize>,
    /// Scheduled churn (sorted by wave) and the application cursor — the
    /// same events the live cluster applies at the same wave boundaries.
    schedule: Vec<ChurnEvent>,
    schedule_cursor: usize,
    /// Slot the next scheduled join admits into (the live cluster's
    /// first-empty-slot discipline: initial clients, then join order).
    next_join_slot: usize,
    /// Membership epoch (bumps on every join/retire, like the live side).
    epoch: u64,
    /// Request-level serving overlay (`Scenario::trace`) — the *same*
    /// tracker type the live cluster drives, at the same wave
    /// boundaries, so live and analytic SLO accounting cross-check.
    /// [`AnalyticSim::run`] closes the books into the recorder.
    tracker: Option<RequestTracker>,
    round: u64,
    /// Per-client round-trip time (uplink with q payload + verdict
    /// downlink), from the scenario's links.
    rtt_s: Vec<f64>,
    /// Virtual clock (seconds since run start).
    clock: f64,
    /// Virtual time each client's next draft arrives at the server.
    ready_at: Vec<f64>,
    /// Optional flight recorder: wave spans and fault instants are
    /// mirrored into the hub *on the virtual clock* (ns), so a simulated
    /// run exports the same Chrome-trace stream a live one does. `None`
    /// (the default) leaves every wave loop untouched.
    observer: Option<std::sync::Arc<ObsHub>>,
    /// Track the observer's spans land on (0 outside sharded mode).
    obs_shard: usize,
}

impl AnalyticSim {
    /// Build a [`SimClient`] from an admission spec.
    fn sim_client(spec: &ClientSpec, scenario: &Scenario) -> SimClient {
        let d = DOMAINS.iter().find(|x| **x == spec.domain).copied().expect("domain");
        SimClient {
            primary_domain: d,
            current_domain: d,
            quality: model_quality(&spec.model),
            stickiness: scenario.domain_stickiness,
            remaining: scenario.max_new_tokens,
            max_new_tokens: scenario.max_new_tokens,
        }
    }

    /// A simulator for the scenario, including its churn schedule: slots
    /// for every scheduled join are pre-built (the same slot-id discipline
    /// as the live cluster), and [`AnalyticSim::run`] applies the events
    /// at the same wave boundaries.
    pub fn from_scenario(scenario: &Scenario, policy: Policy) -> AnalyticSim {
        let cfg = SimConfig::from_scenario(scenario);
        let mut clients: Vec<SimClient> = (0..scenario.num_clients)
            .map(|i| {
                Self::sim_client(
                    &ClientSpec {
                        model: scenario.draft_model(i).to_string(),
                        domain: scenario.domain(i).to_string(),
                        link: scenario.link(i),
                    },
                    scenario,
                )
            })
            .collect();
        let mut links: Vec<crate::configsys::LinkConfig> =
            (0..scenario.num_clients).map(|i| scenario.link(i)).collect();
        let schedule = scenario.churn.sorted();
        for ev in &schedule {
            if let ChurnKind::Join(spec) = &ev.kind {
                clients.push(Self::sim_client(spec, scenario));
                links.push(spec.link.clone());
            }
        }
        Self::with_links(cfg, clients, links, scenario, policy, schedule)
    }

    pub fn new(
        cfg: SimConfig,
        clients: Vec<SimClient>,
        scenario: &Scenario,
        policy: Policy,
    ) -> AnalyticSim {
        let links = (0..clients.len()).map(|i| scenario.link(i)).collect();
        Self::with_links(cfg, clients, links, scenario, policy, Vec::new())
    }

    fn with_links(
        cfg: SimConfig,
        clients: Vec<SimClient>,
        links: Vec<crate::configsys::LinkConfig>,
        scenario: &Scenario,
        policy: Policy,
        schedule: Vec<ChurnEvent>,
    ) -> AnalyticSim {
        // Slot universe = initial clients + one slot per scheduled join;
        // only the initial clients start as members.
        let slots = clients.len();
        let n = scenario.num_clients.min(slots);
        let initial = (cfg.capacity / n.max(1)).min(cfg.max_draft);
        let mut core = RoundCore::new(
            slots,
            scenario.eta,
            scenario.beta,
            policy,
            cfg.seed,
            cfg.capacity,
            initial,
        );
        for i in n..slots {
            core.set_member(i, false);
            core.set_outstanding(i, 0);
        }
        // RTT from the per-slot links: uplink carries the q payload (the
        // dominant term), downlink the tiny verdict.
        let up_bytes = draft_msg_bytes(64, cfg.max_draft, 256);
        let rtt_s: Vec<f64> = links
            .iter()
            .map(|link| {
                let l = Link::new(link.clone());
                l.mean_delay(up_bytes).as_secs_f64()
                    + l.mean_delay(verdict_msg_bytes()).as_secs_f64()
            })
            .collect();
        let ready_at: Vec<f64> = (0..slots)
            .map(|i| rtt_s[i] + cfg.draft_token_s * initial as f64)
            .collect();
        let tracker = if scenario.trace.is_some() {
            let trace = RequestTrace::from_scenario(scenario, slots)
                .expect("resolve the scenario's request trace");
            let mut t = RequestTracker::new(trace, slots);
            if scenario.stream_metrics {
                t.stream();
            }
            Some(t)
        } else {
            None
        };
        if scenario.stream_metrics {
            core.recorder.stream();
        }
        AnalyticSim {
            rng: Rng::new(cfg.seed ^ 0xAAA),
            alloc: vec![initial; slots],
            core,
            members: (0..n).collect(),
            schedule,
            schedule_cursor: 0,
            next_join_slot: n,
            epoch: 0,
            tracker,
            clients,
            cfg,
            round: 0,
            rtt_s,
            clock: 0.0,
            ready_at,
            observer: None,
            obs_shard: 0,
        }
    }

    /// Virtual seconds elapsed (both modes advance it).
    pub fn virtual_time(&self) -> f64 {
        self.clock
    }

    /// Attach a flight recorder: every subsequent wave lands one span on
    /// `hub`'s `shard` track, stamped with the virtual clock in ns, and
    /// chaos faults mirror as instants — the analytic emitter behind
    /// `goodspeed sim --trace-out`.
    pub fn set_observer(&mut self, hub: std::sync::Arc<ObsHub>, shard: usize) {
        self.observer = Some(hub);
        self.obs_shard = shard;
    }

    /// Mirror the wave that just advanced the clock into the recorder.
    fn observe_wave(&self, recv_ns: u64, verify_ns: u64) {
        if let Some(hub) = &self.observer {
            hub.wave_span_at(
                self.obs_shard,
                self.round,
                (self.clock * 1e9) as u64,
                recv_ns,
                verify_ns,
                0,
            );
        }
    }

    /// Mirror a fault instant at the current virtual time.
    fn observe_fault(&self, kind: &str) {
        if let Some(hub) = &self.observer {
            hub.note_fault_at(self.obs_shard, kind, (self.clock * 1e9) as u64);
        }
    }

    /// Membership epoch (0 until the first churn event applies).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Per-client RTTs the wave model uses (test/inspection hook).
    pub fn rtt_s(&self) -> &[f64] {
        &self.rtt_s
    }

    /// The run's metrics (delegates to the shared core).
    pub fn recorder(&self) -> &Recorder {
        &self.core.recorder
    }

    /// The core's estimators (delegates to the shared core).
    pub fn estimators(&self) -> &Estimators {
        &self.core.estimators
    }

    /// Swap the allocation policy (utility ablations).
    pub fn set_allocator(&mut self, alloc: Box<dyn Allocator>) {
        self.core.set_allocator(alloc);
    }

    /// Restrict this simulator to a shard's client subset: only members
    /// draft/verify here, and only members count toward the core's budget
    /// reservation. `members` must be non-empty outside trivial tests.
    pub fn set_members(&mut self, mut members: Vec<usize>) {
        members.sort_unstable();
        members.dedup();
        let n = self.clients.len();
        for i in 0..n {
            self.core.set_member(i, members.binary_search(&i).is_ok());
        }
        // Trace-driven runs: this simulator accounts only its own members'
        // request streams (the others' books live on their own shard).
        if let Some(tracker) = &mut self.tracker {
            tracker.retain_members(&members);
        }
        self.members = members;
    }

    pub fn members(&self) -> &[usize] {
        &self.members
    }

    /// Chaos: adopt `client` into this restricted simulator (shard-crash
    /// migration, or the move home on recovery). With `prior = None` the
    /// estimators re-seed from this shard's population prior — the rule
    /// the live pool applies when a crashed shard's clients arrive;
    /// `Some((α̂, X^β, observations))` carries migrated estimator state,
    /// like the live rebalancer's Join handoff. Request books never
    /// move: a migrated client's in-flight trace requests stay (and
    /// close as censored) on the simulator that owned them, mirroring
    /// the live pool's censored-handoff epilogue.
    pub fn adopt_member(&mut self, client: usize, prior: Option<(f64, f64, u64)>) {
        if self.members.contains(&client) {
            return;
        }
        match prior {
            Some((a, x, t)) => {
                self.core.estimators.alpha_hat[client] = a;
                self.core.estimators.x_beta[client] = x;
                self.core.estimators.set_observations(client, t);
            }
            None => self.core.estimators.seed_from_population(client, &self.members),
        }
        let grant = self.core.admit_member(client, self.cfg.max_draft);
        self.alloc[client] = grant;
        self.ready_at[client] =
            self.clock + self.rtt_s[client] + self.cfg.draft_token_s * grant as f64;
        self.members.push(client);
        self.members.sort_unstable();
    }

    /// Chaos: release `client` from this simulator — the inverse of
    /// [`AnalyticSim::adopt_member`]. Frees its budget reservation
    /// through the same retirement path churn drains use.
    pub fn release_member(&mut self, client: usize) {
        if !self.members.contains(&client) {
            return;
        }
        self.core.retire_member(client);
        self.members.retain(|&c| c != client);
    }

    /// Chaos: scale `client`'s round trip by `factor` — the analytic
    /// application of [`Link::degraded`] over a partition window. An
    /// in-flight draft is delayed by the same inflation; healing
    /// (`factor < 1`) only restores the rate, because a draft already in
    /// the air cannot un-delay. Power-of-two factors restore the
    /// original RTT bit-exactly at the heal wave.
    pub fn scale_rtt(&mut self, client: usize, factor: f64) {
        let extra = self.rtt_s[client] * (factor - 1.0);
        if extra > 0.0 {
            self.ready_at[client] = self.ready_at[client].max(self.clock) + extra;
        }
        self.rtt_s[client] *= factor;
    }

    /// Chaos: stall `client` for `count` redraft cycles — the analytic
    /// model of a drop burst. The live closed loop has no retransmit (a
    /// dropped draft would wedge the client forever), so the simulator
    /// charges the stall those drops would become.
    pub fn stall_client(&mut self, client: usize, count: u32) {
        let redraft = self.rtt_s[client] + self.cfg.draft_token_s * self.alloc[client] as f64;
        self.ready_at[client] = self.ready_at[client].max(self.clock) + count as f64 * redraft;
    }

    /// True per-client α vector (ground truth for regret analysis).
    pub fn true_alphas(&self) -> Vec<f64> {
        self.clients.iter().map(|c| c.true_alpha()).collect()
    }

    /// Draw one client's verification outcome: per-node indicators
    /// `clamp(α + noise)` — same mean as the real min(1, p/q) ratios;
    /// acceptance draws r_j ≤ ratio_j. Chain mode runs the legacy loop
    /// (bit-identical RNG stream); tree shapes walk the same `shaped`
    /// topology the live draft server builds, advancing a level when any
    /// sibling try accepts (the indicator abstraction of `verify_tree`'s
    /// sequential residual scheme). Also advances the client's request
    /// lifecycle + Markov domain switching. Returns
    /// `(nodes, accepted, goodput, mean_ratio, spec_depth)`.
    fn verify_one(&mut self, i: usize) -> (usize, usize, usize, f64, usize) {
        let budget = self.alloc[i];
        let alpha = self.clients[i].true_alpha();
        let (s, accepted, ratio_sum, spec_depth) = if self.cfg.spec_shape.is_chain() {
            let mut accepted = 0usize;
            let mut ratio_sum = 0.0f64;
            let mut rejected = false;
            for _ in 0..budget {
                let ratio =
                    (alpha + self.cfg.indicator_noise * self.rng.normal()).clamp(0.0, 1.0);
                ratio_sum += ratio;
                if !rejected {
                    if self.rng.f64() <= ratio {
                        accepted += 1;
                    } else {
                        rejected = true;
                    }
                }
            }
            (budget, accepted, ratio_sum, budget)
        } else {
            let (arity, depth) = match self.cfg.spec_shape {
                SpecShape::Tree { arity, depth } => (arity, depth),
                // The live adaptive rule uses the client's observed
                // acceptance rate; the analytic counterpart feeds the
                // same rule the estimator's α̂.
                SpecShape::Adaptive => adaptive_profile(self.core.estimators.alpha_hat[i]),
                SpecShape::Chain => unreachable!("chain handled above"),
            };
            let tree = DraftTree::shaped(
                arity,
                depth,
                budget,
                self.cfg.verify_rows,
                self.cfg.max_draft,
            );
            let n = tree.len();
            let mut on_path = vec![false; n];
            // Slot 0 = the root; slot c + 1 = node c: whether a child of
            // that node already accepted (sibling tries stop there).
            let mut descended = vec![false; n + 1];
            let mut accepted = 0usize;
            let mut ratio_sum = 0.0f64;
            for c in 0..n {
                let ratio =
                    (alpha + self.cfg.indicator_noise * self.rng.normal()).clamp(0.0, 1.0);
                ratio_sum += ratio;
                let (pslot, parent_on_path) = match tree.parent_of(c) {
                    None => (0, true),
                    Some(p) => (p + 1, on_path[p]),
                };
                let attempted = parent_on_path && !descended[pslot];
                if attempted && self.rng.f64() <= ratio {
                    on_path[c] = true;
                    descended[pslot] = true;
                    accepted += 1;
                }
            }
            (n, accepted, ratio_sum, tree.max_depth())
        };
        let goodput = accepted + 1;
        let mean_ratio = if s == 0 { 1.0 } else { ratio_sum / s as f64 };

        // Request lifecycle + domain switching.
        let c = &mut self.clients[i];
        c.remaining = c.remaining.saturating_sub(goodput);
        if c.remaining == 0 {
            c.remaining = c.max_new_tokens;
            c.current_domain = if self.rng.bool(c.stickiness) {
                c.primary_domain
            } else {
                loop {
                    let d = *self.rng.choose(&DOMAINS);
                    if d != c.primary_domain {
                        break d;
                    }
                }
            };
        }
        (s, accepted, goodput, mean_ratio, spec_depth)
    }

    /// Advance one sync barrier round (all members); returns realized
    /// goodputs in member order. The RNG stream is identical to the
    /// pre-core simulator.
    pub fn step(&mut self) -> Vec<usize> {
        let members = self.members.clone();
        // Request boundary: promote due arrivals, refresh the idle mask,
        // publish SLO headroom — the same tracker call the live cluster
        // makes at its wave boundary.
        if let Some(tracker) = &mut self.tracker {
            tracker.sync_wave_start(&mut self.core, self.round, &members);
        }
        let mut obs = Vec::with_capacity(members.len());
        let mut goodputs = Vec::with_capacity(members.len());
        for &i in &members {
            let (s, accepted, goodput, mean_ratio, spec_depth) = self.verify_one(i);
            obs.push(WaveObs {
                client_id: i,
                s_used: s,
                accepted,
                goodput,
                mean_ratio,
                spec_depth,
                max_next: self.cfg.max_draft,
            });
            goodputs.push(goodput);
        }
        // Virtual clock: the barrier waits for the slowest member's draft
        // + uplink, then runs one batched verify.
        let recv_s = obs
            .iter()
            .map(|o| self.rtt_s[o.client_id] + self.cfg.draft_token_s * o.s_used as f64)
            .fold(0.0f64, f64::max);
        let next = self.core.finish_wave(
            self.round,
            &obs,
            (recv_s * 1e9) as u64,
            (self.cfg.verify_s * 1e9) as u64,
        );
        for (j, &i) in members.iter().enumerate() {
            self.alloc[i] = next[j];
        }
        if let Some(tracker) = &mut self.tracker {
            let outcomes: Vec<(usize, usize)> =
                obs.iter().map(|o| (o.client_id, o.goodput)).collect();
            tracker.sync_wave_end(self.round, &outcomes);
        }
        self.clock += recv_s + self.cfg.verify_s;
        self.observe_wave((recv_s * 1e9) as u64, (self.cfg.verify_s * 1e9) as u64);
        self.round += 1;
        goodputs
    }

    /// Advance one async wave: fire on wave-fill or the batching-window
    /// deadline (whichever comes first after the wave's first arrival),
    /// verify the ready member subset, reschedule only its members.
    /// Returns the wave's `(client_id, goodput)` pairs.
    pub fn step_wave(&mut self) -> Vec<(usize, usize)> {
        // Request boundary (same rules as the sync step).
        if let Some(tracker) = &mut self.tracker {
            let members = self.members.clone();
            tracker.sync_wave_start(&mut self.core, self.round, &members);
        }
        let m = self.members.len();
        // `min_wave_fill` is pre-resolved by `SimConfig::from_scenario`
        // (Scenario::effective_wave_fill); clamp defensively for
        // hand-built configs that kept the raw `0 = all` sentinel, and to
        // the member count in sharded mode.
        let fill = if self.cfg.min_wave_fill == 0 {
            m
        } else {
            self.cfg.min_wave_fill.min(m)
        };
        // Arrival order of the members' in-flight drafts.
        let mut order: Vec<usize> = self.members.clone();
        order.sort_by(|&a, &b| self.ready_at[a].total_cmp(&self.ready_at[b]));
        let t_first = self.ready_at[order[0]];
        let deadline = t_first + self.cfg.batch_window_s;
        let t_fill = self.ready_at[order[fill - 1]];
        // The verification server is single-threaded: a wave can never
        // fire before the previous verify finished (self.clock), however
        // early its drafts arrived — arrivals during the busy period are
        // simply drained into this wave, like the real leader's
        // opportunistic drain.
        let fire_t = (if t_fill <= deadline { t_fill } else { deadline }).max(self.clock);
        let mut wave_members: Vec<usize> =
            order.into_iter().filter(|&i| self.ready_at[i] <= fire_t).collect();
        wave_members.sort_unstable(); // verify in ascending client id

        let mut obs = Vec::with_capacity(wave_members.len());
        for &i in &wave_members {
            let (s, accepted, goodput, mean_ratio, spec_depth) = self.verify_one(i);
            obs.push(WaveObs {
                client_id: i,
                s_used: s,
                accepted,
                goodput,
                mean_ratio,
                spec_depth,
                max_next: self.cfg.max_draft,
            });
        }
        // Sparse estimator update + allocation over the wave's live set
        // with absent members' in-flight grants reserved (the same core
        // invariant the real leader enforces: Σ alloc ≤ C at all times).
        let wait_ns = (((fire_t - self.clock).max(0.0)) * 1e9) as u64;
        let next = self.core.finish_wave(
            self.round,
            &obs,
            wait_ns,
            (self.cfg.verify_s * 1e9) as u64,
        );
        let t_done = fire_t + self.cfg.verify_s;
        for (j, &i) in wave_members.iter().enumerate() {
            self.alloc[i] = next[j];
            self.ready_at[i] =
                t_done + self.rtt_s[i] + self.cfg.draft_token_s * next[j] as f64;
        }
        let outcomes: Vec<(usize, usize)> = obs.iter().map(|o| (o.client_id, o.goodput)).collect();
        if let Some(tracker) = &mut self.tracker {
            tracker.sync_wave_end(self.round, &outcomes);
        }
        self.clock = t_done;
        self.observe_wave(wait_ns, (self.cfg.verify_s * 1e9) as u64);
        self.round += 1;
        outcomes
    }

    /// Apply churn events due at the current wave boundary — the same
    /// admit/drain rules the live cluster runs ([`RoundCore::admit_member`]
    /// + population-prior estimator seeding; drains grant 0 and retire
    /// after their final wave). With an empty membership, pending events
    /// fire immediately (no waves can pass to reach them otherwise).
    fn churn_boundary(&mut self) {
        loop {
            let due = self.schedule_cursor < self.schedule.len()
                && (self.schedule[self.schedule_cursor].at_wave <= self.round
                    || self.members.is_empty());
            if !due {
                break;
            }
            let ev = self.schedule[self.schedule_cursor].clone();
            self.schedule_cursor += 1;
            match ev.kind {
                ChurnKind::Join(_) => {
                    // Slot ids follow the join order: initial clients,
                    // then one slot per join event (pre-built).
                    let slot = self.next_join_slot;
                    self.next_join_slot += 1;
                    self.core.estimators.seed_from_population(slot, &self.members);
                    let grant = self.core.admit_member(slot, self.cfg.max_draft);
                    self.alloc[slot] = grant;
                    self.ready_at[slot] = self.clock
                        + self.rtt_s[slot]
                        + self.cfg.draft_token_s * grant as f64;
                    self.members.push(slot);
                    self.members.sort_unstable();
                    self.epoch += 1;
                    self.core.recorder.note_membership(MembershipEvent {
                        wave: self.round,
                        epoch: self.epoch,
                        joined: vec![(slot, grant)],
                        left: vec![],
                        members: self.members.clone(),
                    });
                }
                ChurnKind::Leave(id) => {
                    if self.members.contains(&id) {
                        self.core.set_draining(id, true);
                    }
                }
            }
        }
    }

    /// Retire any draining participants of the wave that just ran (their
    /// final verdict has been delivered — the live drain semantics).
    fn retire_drained(&mut self, participants: &[usize]) {
        for &id in participants {
            if self.core.is_draining(id) {
                self.core.retire_member(id);
                if let Some(tracker) = &mut self.tracker {
                    tracker.untrack(id, self.round);
                }
                self.members.retain(|&m| m != id);
                self.epoch += 1;
                self.core.recorder.note_membership(MembershipEvent {
                    wave: self.round,
                    epoch: self.epoch,
                    joined: vec![],
                    left: vec![id],
                    members: self.members.clone(),
                });
            }
        }
    }

    /// Run the configured workload: `rounds` barrier rounds in sync mode,
    /// or waves until the same total verification budget (`rounds ×
    /// |initial members|` client-rounds) is consumed in async mode.
    /// Scheduled churn is applied at wave boundaries either way.
    pub fn run(&mut self) {
        match self.cfg.mode {
            CoordMode::Sync => {
                for _ in 0..self.cfg.rounds {
                    self.churn_boundary();
                    if self.members.is_empty() {
                        break;
                    }
                    let members = self.members.clone();
                    self.step();
                    self.retire_drained(&members);
                }
            }
            CoordMode::Async => {
                let budget = self.cfg.rounds * self.members.len() as u64;
                while self.recorder().participation().iter().sum::<u64>() < budget {
                    self.churn_boundary();
                    if self.members.is_empty() {
                        break;
                    }
                    let wave: Vec<usize> =
                        self.step_wave().into_iter().map(|(id, _)| id).collect();
                    self.retire_drained(&wave);
                }
            }
        }
        self.close_request_books();
    }

    /// Trace-driven runs: close the request books into the recorder
    /// (expired requests become recorded misses, pending ones are
    /// censored) — the same epilogue the live cluster runs. Idempotent:
    /// the tracker is consumed on the first call.
    pub fn close_request_books(&mut self) {
        if let Some(mut tracker) = self.tracker.take() {
            tracker.finish(self.round);
            let (requests, slo_goodput, censored, sketch) = tracker.into_report();
            self.core.recorder.requests = requests;
            self.core.recorder.slo_goodput = slo_goodput;
            self.core.recorder.requests_censored = censored;
            self.core.recorder.request_sketch = sketch;
        }
    }

    /// Pin client `i`'s *true* acceptance rate to `alpha` (stationary
    /// domains): live-vs-analytic cross-checks use this to evaluate the
    /// analytic model at a live run's *observed* acceptance rates, so
    /// the comparison is engine-independent.
    pub fn pin_alpha(&mut self, i: usize, alpha: f64) {
        let c = &mut self.clients[i];
        c.stickiness = 1.0;
        c.current_domain = c.primary_domain;
        c.quality = alpha.clamp(0.02, 0.98) / domain_alpha(c.primary_domain);
    }
}

/// Outcome of the sharded analytic run: one restricted simulator per
/// verification shard plus the final hierarchical budget split.
pub struct ShardedSimOutcome {
    pub shards: Vec<AnalyticSim>,
    pub budgets: Vec<usize>,
    /// Per-sweep (one wave attempt per live shard) per-client delivered
    /// tokens. Recorded only when the scenario carries a fault schedule —
    /// chaos-free runs leave it empty and take the exact pre-chaos code
    /// path. `benches/chaos.rs` windows its goodput/fairness recovery
    /// envelopes over this series.
    pub wave_tokens: Vec<Vec<u64>>,
}

impl ShardedSimOutcome {
    /// Aggregate virtual-time goodput rate: total tokens over the slowest
    /// shard's clock (shards run in parallel in a real pool).
    pub fn aggregate_rate(&self) -> f64 {
        let tokens: f64 = self
            .shards
            .iter()
            .map(|s| s.recorder().cum_goodput().iter().sum::<f64>())
            .sum();
        let wall = self
            .shards
            .iter()
            .map(|s| s.virtual_time())
            .fold(0.0f64, f64::max);
        tokens / wall.max(1e-12)
    }

    /// Merged per-client average goodput per participated wave (clients
    /// are disjoint across shards).
    pub fn avg_goodput(&self) -> Vec<f64> {
        let n = self.shards.first().map_or(0, |s| s.clients.len());
        let mut out = vec![0.0; n];
        for sim in &self.shards {
            for &i in sim.members() {
                out[i] = sim.recorder().avg_goodput()[i];
            }
        }
        out
    }

    /// Merged per-client SLO-goodput totals (trace-driven runs): clients
    /// are disjoint across shards after [`AnalyticSim::set_members`]
    /// restricted each tracker, so per-slot sums are exact.
    pub fn slo_goodput(&self) -> Vec<f64> {
        let n = self.shards.first().map_or(0, |s| s.clients.len());
        let mut out = vec![0.0; n];
        for sim in &self.shards {
            for (i, &v) in sim.recorder().slo_goodput.iter().enumerate() {
                out[i] += v;
            }
        }
        out
    }

    /// Merged request-level SLO summary across the shards' disjoint
    /// request books (None for non-trace runs). Retained shards
    /// concatenate records; streaming shards merge sketches — a mix
    /// folds retained records into the merged sketch.
    pub fn slo_summary(&self) -> Option<SloSummary> {
        if !self.shards.iter().any(|s| s.recorder().has_requests()) {
            return None;
        }
        let censored: u64 = self.shards.iter().map(|s| s.recorder().requests_censored).sum();
        if self.shards.iter().any(|s| s.recorder().request_sketch.is_some()) {
            let mut sk = RequestSketch::new();
            for sim in &self.shards {
                let r = sim.recorder();
                if let Some(other) = &r.request_sketch {
                    sk.merge(other);
                }
                for rec in &r.requests {
                    sk.push(rec);
                }
            }
            return Some(sk.summary(censored));
        }
        let records: Vec<_> = self
            .shards
            .iter()
            .flat_map(|s| s.recorder().requests.iter().cloned())
            .collect();
        Some(summarize_requests(&records, censored))
    }

    /// All fault/recovery events across the shard recorders (recorded
    /// order per shard; chaos-free runs return an empty list).
    pub fn faults(&self) -> Vec<FaultRecord> {
        self.shards.iter().flat_map(|s| s.recorder().faults.iter().cloned()).collect()
    }

    /// Waves-to-recover for every completed crash/recover pair.
    pub fn time_to_recover(&self) -> Vec<u64> {
        self.shards
            .iter()
            .flat_map(|s| s.recorder().time_to_recover.iter().copied())
            .collect()
    }

    /// Mean goodput per delivered verdict (steady-state tokens/verdict —
    /// the timing-free quantity that must agree with the live pool).
    pub fn goodput_per_verdict(&self) -> f64 {
        let tokens: f64 = self
            .shards
            .iter()
            .map(|s| s.recorder().cum_goodput().iter().sum::<f64>())
            .sum();
        let verdicts: u64 = self
            .shards
            .iter()
            .map(|s| s.recorder().participation().iter().sum::<u64>())
            .sum();
        tokens / (verdicts as f64).max(1.0)
    }
}

/// Hierarchical budgets for the analytic pool — the *same* split rule the
/// live controller applies (`sched::gradient::split_budget_by_members`),
/// fed from the shard sims' own estimator state. Client i's estimates
/// live on the (single) shard that owns it, so gathering per-shard keeps
/// the published table exact.
fn sharded_budgets(capacity: usize, max_draft: usize, shards: &[AnalyticSim]) -> Vec<usize> {
    let n = shards.first().map_or(0, |s| s.clients.len());
    let mut alpha_hat = vec![0.5; n];
    let mut x_beta = vec![1.0; n];
    let mut members_per_shard = Vec::with_capacity(shards.len());
    for sim in shards {
        let est = sim.estimators();
        for &i in sim.members() {
            alpha_hat[i] = est.alpha_hat[i];
            x_beta[i] = est.x_beta[i];
        }
        members_per_shard.push(sim.members().to_vec());
    }
    split_budget_by_members(capacity, max_draft, &members_per_shard, &alpha_hat, &x_beta)
}

/// RTT inflation a partitioned client sees while traffic routes around
/// the outage — one scalar standing in for [`Link::degraded`]'s
/// latency × bandwidth dilation. A power of two, so the heal wave
/// restores the original RTT bit-exactly.
const PARTITION_RTT_FACTOR: f64 = 8.0;

/// Shard currently serving `client`, if any. Faults can target clients
/// that already churned away; those ops are skipped, like the live
/// driver's fault-skipped path.
fn owner_of(shards: &[AnalyticSim], client: usize) -> Option<usize> {
    shards.iter().position(|s| s.members().contains(&client))
}

/// Apply one compiled fault to the sharded analytic pool — the simulator
/// half of the live pool driver's fault path, consuming the same
/// [`FaultSchedule::compiled`](crate::chaos::FaultSchedule::compiled)
/// list on the same pooled wave clock. Crash migration re-seeds movers
/// from the adopting shard's population prior (the live crash-handoff
/// rule); recovery returns the shard's home clients immediately,
/// carrying their current estimates — the instantaneous stand-in for the
/// live rebalancer's gradual one-client-per-tick repatriation (see
/// DESIGN.md §9 for the envelope-comparison caveats).
fn apply_sim_fault(
    shards: &mut [AnalyticSim],
    live: &mut [bool],
    crashed_at: &mut [Option<u64>],
    wave: u64,
    op: FaultOp,
) {
    let m = shards.len();
    match op {
        FaultOp::Crash { shard } => {
            if !live[shard] {
                return;
            }
            let survivors: Vec<usize> = (0..m).filter(|&s| s != shard && live[s]).collect();
            if survivors.is_empty() {
                shards[shard].core.recorder.note_fault(FaultRecord {
                    wave,
                    shard,
                    kind: "fault-skipped".into(),
                    detail: "crash without a live survivor; ignored".into(),
                });
                shards[shard].observe_fault("fault-skipped");
                return;
            }
            live[shard] = false;
            crashed_at[shard] = Some(wave);
            let movers = shards[shard].members().to_vec();
            for (k, &c) in movers.iter().enumerate() {
                shards[shard].release_member(c);
                shards[survivors[k % survivors.len()]].adopt_member(c, None);
            }
            shards[shard].core.recorder.note_fault(FaultRecord {
                wave,
                shard,
                kind: "shard-crash".into(),
                detail: format!(
                    "{} clients migrated to surviving shards {survivors:?}",
                    movers.len()
                ),
            });
            shards[shard].observe_fault("shard-crash");
        }
        FaultOp::Recover { shard } => {
            if live[shard] {
                return;
            }
            let Some(at) = crashed_at[shard].take() else { return };
            live[shard] = true;
            let mut moved = 0usize;
            for src in 0..m {
                if src == shard {
                    continue;
                }
                let home: Vec<usize> =
                    shards[src].members().iter().copied().filter(|&c| c % m == shard).collect();
                for c in home {
                    let est = shards[src].estimators();
                    let prior = (est.alpha_hat[c], est.x_beta[c], est.observations(c));
                    shards[src].release_member(c);
                    shards[shard].adopt_member(c, Some(prior));
                    moved += 1;
                }
            }
            let rec = &mut shards[shard].core.recorder;
            rec.time_to_recover.push(wave.saturating_sub(at).max(1));
            rec.note_fault(FaultRecord {
                wave,
                shard,
                kind: "shard-recover".into(),
                detail: format!("re-admitted; {moved} home clients returned"),
            });
            shards[shard].observe_fault("shard-recover");
        }
        FaultOp::PartitionStart { client, until } => {
            // Inflate in every simulator, so a crash migration during
            // the outage window carries the degraded RTT with it.
            for sim in shards.iter_mut() {
                sim.scale_rtt(client, PARTITION_RTT_FACTOR);
            }
            let s = owner_of(shards, client).unwrap_or(0);
            shards[s].core.recorder.note_fault(FaultRecord {
                wave,
                shard: s,
                kind: "partition".into(),
                detail: format!(
                    "client {client} routed around an outage until wave {until} \
                     (rtt ×{PARTITION_RTT_FACTOR})"
                ),
            });
            shards[s].observe_fault("partition");
        }
        FaultOp::PartitionHeal { client } => {
            for sim in shards.iter_mut() {
                sim.scale_rtt(client, 1.0 / PARTITION_RTT_FACTOR);
            }
            let s = owner_of(shards, client).unwrap_or(0);
            shards[s].core.recorder.note_fault(FaultRecord {
                wave,
                shard: s,
                kind: "partition-heal".into(),
                detail: format!("client {client} uplink restored"),
            });
            shards[s].observe_fault("partition-heal");
        }
        FaultOp::Drop { client, count } => {
            let Some(s) = owner_of(shards, client) else { return };
            shards[s].stall_client(client, count);
            shards[s].core.recorder.note_fault(FaultRecord {
                wave,
                shard: s,
                kind: "drop-burst".into(),
                detail: format!("{count} drafts dropped; client {client} stalls to redraft"),
            });
            shards[s].observe_fault("drop-burst");
        }
        FaultOp::Duplicate { client, count } => {
            let Some(s) = owner_of(shards, client) else { return };
            shards[s].core.recorder.note_fault(FaultRecord {
                wave,
                shard: s,
                kind: "duplicate-burst".into(),
                detail: format!("{count} duplicate drafts discarded before verification"),
            });
            shards[s].observe_fault("duplicate-burst");
        }
    }
}

/// Analytic counterpart of the live verifier pool: `num_verifiers`
/// restricted simulators (client i on shard i mod M), each consuming its
/// budget slice, with the split recomputed every
/// `shard_rebalance_every` waves from the shards' own estimator state.
/// Runs until the global verification budget (`rounds × num_clients`
/// verdicts) is consumed. Pressure-driven client rebalancing is not
/// modeled (the steady-state scheduling and accounting are the
/// shared-core code either way), but the scenario's fault schedule is:
/// shard crashes migrate the victims to survivors and recovery brings
/// them home, on the same pooled wave clock the live driver uses, so
/// live and analytic recovery envelopes cross-check.
pub fn run_sharded(scenario: &Scenario, policy: Policy) -> ShardedSimOutcome {
    run_sharded_with(scenario, policy, |_| {})
}

/// [`run_sharded`] with a per-shard configuration hook applied after the
/// member restriction and before any wave runs — live-vs-analytic
/// cross-checks use it to pin each client's acceptance rate to the value
/// a live run observed.
pub fn run_sharded_with(
    scenario: &Scenario,
    policy: Policy,
    mut configure: impl FnMut(&mut AnalyticSim),
) -> ShardedSimOutcome {
    let m = scenario.num_verifiers.max(1);
    let n = scenario.num_clients;
    let mut shards: Vec<AnalyticSim> = (0..m)
        .map(|s| {
            let mut sim = AnalyticSim::from_scenario(scenario, policy);
            sim.set_members((0..n).filter(|i| i % m == s).collect());
            configure(&mut sim);
            sim
        })
        .collect();
    let mut budgets = sharded_budgets(scenario.capacity, scenario.max_draft, &shards);
    for (sim, &b) in shards.iter_mut().zip(&budgets) {
        sim.core.set_capacity(b);
    }
    let total: u64 = scenario.rounds.saturating_mul(n as u64);
    let every = scenario.shard_rebalance_every;
    let mut delivered = 0u64;
    let mut waves = 0u64;
    // The mirrored fault schedule, on the live driver's pooled wave
    // clock (total shard waves ÷ M). Empty schedules leave every branch
    // below untaken — chaos-free runs are bit-identical to the
    // pre-chaos simulator.
    let chaos: Vec<(u64, FaultOp)> = scenario.chaos.compiled();
    let chaos_active = !chaos.is_empty();
    let mut chaos_cursor = 0usize;
    let mut live = vec![true; m];
    let mut crashed_at: Vec<Option<u64>> = vec![None; m];
    let slots = shards.first().map_or(0, |s| s.clients.len());
    let mut wave_tokens: Vec<Vec<u64>> = Vec::new();
    'run: loop {
        // Fault boundary: apply every op due before this sweep forms,
        // so the live and analytic paths see one schedule on one clock.
        while chaos_cursor < chaos.len() && chaos[chaos_cursor].0 <= waves / m as u64 {
            let (at, op) = chaos[chaos_cursor].clone();
            chaos_cursor += 1;
            apply_sim_fault(&mut shards, &mut live, &mut crashed_at, at, op);
        }
        let mut row = vec![0u64; if chaos_active { slots } else { 0 }];
        for s in 0..m {
            if !live[s] || shards[s].members().is_empty() {
                continue;
            }
            let outcomes = shards[s].step_wave();
            delivered += outcomes.len() as u64;
            if chaos_active {
                for &(c, g) in &outcomes {
                    row[c] += g as u64;
                }
            }
            waves += 1;
            if every > 0 && waves % every == 0 {
                budgets = sharded_budgets(scenario.capacity, scenario.max_draft, &shards);
                for (sim, &b) in shards.iter_mut().zip(&budgets) {
                    sim.core.set_capacity(b);
                }
            }
            if delivered >= total {
                if chaos_active {
                    wave_tokens.push(row);
                }
                break 'run;
            }
        }
        if chaos_active {
            wave_tokens.push(row);
        }
    }
    // Trace-driven runs: close each shard's request books (disjoint
    // client subsets — the merged view is exact concatenation).
    for sim in shards.iter_mut() {
        sim.close_request_books();
    }
    ShardedSimOutcome { shards, budgets, wave_tokens }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::utility::LogUtility;

    fn sim(policy: Policy, clients: usize, rounds: u64) -> AnalyticSim {
        let mut s = Scenario::preset("qwen-8c-150").unwrap();
        s.num_clients = clients;
        s.rounds = rounds;
        AnalyticSim::from_scenario(&s, policy)
    }

    #[test]
    fn runs_fast_and_respects_capacity() {
        let mut s = sim(Policy::GoodSpeed, 8, 300);
        s.run();
        assert_eq!(s.recorder().rounds.len(), 300);
        for r in &s.recorder().rounds {
            let used: usize = r.clients.iter().map(|c| c.s_used).sum();
            assert!(used <= 20);
        }
    }

    #[test]
    fn observer_mirrors_waves_on_the_virtual_clock() {
        use crate::obs::{flight::KIND_WAVE, ObsHub, ObsOptions};
        use std::sync::Arc;
        let hub = Arc::new(ObsHub::new(1, 8, &ObsOptions::default()));
        let mut s = sim(Policy::GoodSpeed, 8, 20);
        s.set_observer(Arc::clone(&hub), 0);
        s.run();
        let events = hub.snapshot_events();
        let waves: Vec<_> = events.iter().filter(|e| e.kind == KIND_WAVE).collect();
        assert_eq!(waves.len(), 20);
        // Span ends ride the virtual clock, not the wall clock: monotone
        // nondecreasing, with the last landing exactly at the final time.
        for w in waves.windows(2) {
            assert!(w[0].end_ns <= w[1].end_ns);
        }
        assert_eq!(waves.last().unwrap().end_ns, (s.virtual_time() * 1e9) as u64);
    }

    #[test]
    fn estimator_tracks_true_alpha() {
        let mut s = sim(Policy::FixedS, 4, 400);
        // Stationary domains for a clean check.
        for c in s.clients.iter_mut() {
            c.stickiness = 1.0;
        }
        s.run();
        for (i, c) in s.clients.iter().enumerate() {
            let est = s.estimators().alpha_hat[i];
            let truth = c.true_alpha();
            assert!(
                (est - truth).abs() < 0.12,
                "client {i}: est {est:.3} vs true {truth:.3}"
            );
        }
    }

    #[test]
    fn goodspeed_beats_baselines_on_log_utility() {
        // The paper's Fig 4 headline: GoodSpeed's U(x̄(T)) tops Fixed-S and
        // Random-S after convergence.
        let u = LogUtility;
        let mut values = Vec::new();
        for p in [Policy::GoodSpeed, Policy::FixedS, Policy::RandomS] {
            let mut s = sim(p, 8, 600);
            s.run();
            values.push(s.recorder().utility_of_avg(&u));
        }
        assert!(
            values[0] > values[1] && values[0] > values[2],
            "U(goodspeed)={:.4} U(fixed)={:.4} U(random)={:.4}",
            values[0],
            values[1],
            values[2]
        );
    }

    #[test]
    fn utility_stabilizes_after_exploration() {
        // Fig 4 shape: early exploration dip, then stabilization — the
        // last-100-rounds utility range must be small.
        let u = LogUtility;
        let mut s = sim(Policy::GoodSpeed, 8, 600);
        let mut curve = Vec::new();
        for _ in 0..600 {
            s.step();
            curve.push(s.recorder().utility_of_avg(&u));
        }
        let tail = &curve[500..];
        let (lo, hi) = tail
            .iter()
            .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &x| (l.min(x), h.max(x)));
        assert!(hi - lo < 0.15, "tail range {}", hi - lo);
        // and the curve must have risen from its early value
        assert!(curve[599] > curve[20]);
    }

    #[test]
    fn heterogeneous_alphas_by_domain() {
        let s = sim(Policy::GoodSpeed, 8, 1);
        let alphas = s.true_alphas();
        let spread = alphas.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            - alphas.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(spread > 0.3, "domains must induce heterogeneity: {alphas:?}");
    }

    fn straggler_sim(mode: CoordMode) -> AnalyticSim {
        let mut s = Scenario::preset("straggler").unwrap();
        s.rounds = 400;
        s.coord_mode = mode;
        AnalyticSim::from_scenario(&s, Policy::GoodSpeed)
    }

    #[test]
    fn async_waves_consume_the_same_budget() {
        let mut s = sim(Policy::GoodSpeed, 4, 100);
        s.cfg.mode = CoordMode::Async;
        s.cfg.min_wave_fill = 2;
        s.run();
        let delivered: u64 = s.recorder().participation().iter().sum();
        assert!(delivered >= 400 && delivered < 400 + 4);
        // Waves carry id-ascending subsets and virtual time advances.
        for r in &s.recorder().rounds {
            assert!(!r.clients.is_empty());
            for w in r.clients.windows(2) {
                assert!(w[0].client_id < w[1].client_id);
            }
        }
        assert!(s.virtual_time() > 0.0);
    }

    #[test]
    fn straggler_links_produce_partial_waves() {
        let mut s = straggler_sim(CoordMode::Async);
        assert!(s.rtt_s()[0] > 3.0 * s.rtt_s()[1], "straggler RTT must dominate");
        s.run();
        let n = s.clients.len();
        let partial =
            s.recorder().rounds.iter().filter(|r| r.clients.len() < n).count();
        assert!(partial > 0, "async mode must fire partial waves around the straggler");
        // The fast clients participate in more waves than the straggler.
        let p = s.recorder().participation().to_vec();
        assert!(p[1] > p[0] && p[2] > p[0] && p[3] > p[0], "{p:?}");
    }

    #[test]
    fn async_recovers_goodput_and_preserves_fairness_under_straggler() {
        // The acceptance-criterion shape, in virtual time: same total
        // verification budget, async finishes sooner ⇒ higher aggregate
        // goodput rate, while per-wave fairness (Jain over accepted
        // tokens per participated wave) stays close to sync.
        use crate::util::stats::jain_index;
        let mut sync = straggler_sim(CoordMode::Sync);
        sync.run();
        let mut asy = straggler_sim(CoordMode::Async);
        asy.run();
        let tokens = |r: &crate::metrics::recorder::Recorder| -> f64 {
            r.cum_goodput().iter().sum()
        };
        let sync_rate = tokens(sync.recorder()) / sync.virtual_time();
        let async_rate = tokens(asy.recorder()) / asy.virtual_time();
        assert!(
            async_rate > sync_rate,
            "async {async_rate:.1} tok/s must beat sync {sync_rate:.1} tok/s"
        );
        let j_sync = jain_index(&sync.recorder().avg_accepted());
        let j_async = jain_index(&asy.recorder().avg_accepted());
        assert!(
            (j_sync - j_async).abs() <= 0.05 * j_sync,
            "fairness drift too large: sync {j_sync:.4} vs async {j_async:.4}"
        );
    }

    /// The tentpole's goodput lever, in the analytic model: the `tree`
    /// preset's binary profile must beat the chain at the exact same node
    /// budget, and the realized shapes must actually branch.
    #[test]
    fn tree_shape_raises_goodput_at_equal_node_budget() {
        let mut s = Scenario::preset("tree").unwrap();
        s.rounds = 300;
        let mut tree_sim = AnalyticSim::from_scenario(&s, Policy::GoodSpeed);
        tree_sim.run();
        s.spec_shape = SpecShape::Chain;
        let mut chain_sim = AnalyticSim::from_scenario(&s, Policy::GoodSpeed);
        chain_sim.run();
        let (gt, gc) = (
            tree_sim.recorder().goodput_per_verdict(),
            chain_sim.recorder().goodput_per_verdict(),
        );
        assert!(gt > gc, "tree {gt:.3} must beat chain {gc:.3} tokens/verdict");
        // Branching really happened: depth < nodes on some records, and
        // node budgets stayed within C either way.
        let branched = tree_sim
            .recorder()
            .rounds
            .iter()
            .flat_map(|r| r.clients.iter())
            .any(|c| c.spec_depth < c.s_used);
        assert!(branched);
        for r in tree_sim.recorder().rounds.iter() {
            let used: usize = r.clients.iter().map(|c| c.s_used).sum();
            assert!(used <= 24, "{used}");
        }
    }

    /// The adaptive shape holds its own: never worse than the fixed chain
    /// on the heterogeneous-α tree preset.
    #[test]
    fn adaptive_shape_not_worse_than_chain() {
        let mut s = Scenario::preset("tree").unwrap();
        s.rounds = 300;
        s.spec_shape = SpecShape::Adaptive;
        let mut ad = AnalyticSim::from_scenario(&s, Policy::GoodSpeed);
        ad.run();
        s.spec_shape = SpecShape::Chain;
        let mut ch = AnalyticSim::from_scenario(&s, Policy::GoodSpeed);
        ch.run();
        assert!(
            ad.recorder().goodput_per_verdict() >= ch.recorder().goodput_per_verdict() * 0.98
        );
    }

    /// Churn model: the `churn` preset's join and leave apply at their
    /// wave boundaries, the joiner converges to a fair share, and the
    /// reservation invariant Σ outstanding ≤ C survives every membership
    /// change.
    #[test]
    fn churn_schedule_applies_at_wave_boundaries() {
        let s = Scenario::preset("churn").unwrap();
        let mut sim = AnalyticSim::from_scenario(&s, Policy::GoodSpeed);
        sim.run();
        // Epochs: one join (wave 80) + one departure (wave 160).
        assert_eq!(sim.epoch(), 2);
        let events = &sim.recorder().membership;
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].joined.len(), 1);
        assert_eq!(events[0].joined[0].0, 4, "joiner takes the first fresh slot");
        assert_eq!(events[0].wave, 80);
        assert_eq!(events[1].left, vec![1]);
        // The departed client participates up to (and including) its
        // drain wave, never after.
        let part = sim.recorder().participation().to_vec();
        assert!(part[1] > 0 && part[1] <= 162, "{part:?}");
        // The joiner serves the back two-thirds of the run.
        assert!(part[4] > 100, "{part:?}");
        // Node budget respected on every wave, through both changes.
        for r in &sim.recorder().rounds {
            let used: usize = r.clients.iter().map(|c| c.s_used).sum();
            assert!(used <= 24, "{used}");
        }
        // Fairness: the joiner's per-wave goodput lands near the
        // survivors' (log-utility equalization).
        let avg = sim.recorder().avg_goodput();
        let survivors = [0usize, 2, 3];
        let mean: f64 =
            survivors.iter().map(|&i| avg[i]).sum::<f64>() / survivors.len() as f64;
        assert!(
            (avg[4] - mean).abs() <= 0.35 * mean,
            "joiner {:.2} vs survivors {:.2}",
            avg[4],
            mean
        );
    }

    /// The joiner's estimators start from the population prior, not the
    /// cold-start prior.
    #[test]
    fn joiner_seeds_from_population_prior() {
        let mut s = Scenario::preset("churn").unwrap();
        s.rounds = 81; // stop right after the join applies
        let mut sim = AnalyticSim::from_scenario(&s, Policy::GoodSpeed);
        sim.run();
        let est = sim.estimators();
        // After 80 waves the resident population has moved well off 0.5;
        // a cold-start joiner would sit exactly at 0.5 before its first
        // wave — population seeding pulls it toward the residents.
        let resident_mean: f64 =
            [0usize, 1, 2, 3].iter().map(|&i| est.alpha_hat[i]).sum::<f64>() / 4.0;
        assert!((resident_mean - 0.5).abs() > 0.05, "residents must have learned");
        assert!(
            (est.alpha_hat[4] - resident_mean).abs() < 0.2,
            "joiner α̂ {:.3} should start near the population {:.3}",
            est.alpha_hat[4],
            resident_mean
        );
    }

    /// Trace-driven model: requests are accounted against the same wave
    /// stream the scheduler sees, idle clients are granted 0 (their
    /// budget water-fills over busy ones), and the SLO series is a
    /// filtered view of raw goodput.
    #[test]
    fn trace_runs_account_requests_and_idle_waves() {
        let s = Scenario::preset("trace").unwrap();
        let mut sim = AnalyticSim::from_scenario(&s, Policy::GoodSpeed);
        sim.run();
        let rec = sim.recorder();
        assert!(rec.has_requests());
        assert!(!rec.requests.is_empty());
        let summary = rec.slo_summary().unwrap();
        assert!(summary.completed > 0);
        assert!((0.0..=1.0).contains(&summary.attainment));
        for (slo, raw) in rec.slo_goodput.iter().zip(rec.cum_goodput()) {
            assert!(slo <= raw + 1e-9);
        }
        // Idle masking: some wave ran one client at a zero grant while
        // another drafted (Poisson gaps ≫ service times guarantee idle
        // stretches).
        let idle_wave = rec.rounds.iter().any(|r| {
            r.clients.iter().any(|c| c.s_used == 0) && r.clients.iter().any(|c| c.s_used > 0)
        });
        assert!(idle_wave, "idle clients must be granted 0 while busy ones draft");
        // Budget respected on every wave regardless of masking.
        for r in &rec.rounds {
            let used: usize = r.clients.iter().map(|c| c.s_used).sum();
            assert!(used <= s.capacity, "{used}");
        }
        // Deterministic: the same scenario replays the same books.
        let mut again = AnalyticSim::from_scenario(&s, Policy::GoodSpeed);
        again.run();
        assert_eq!(again.recorder().requests.len(), rec.requests.len());
        assert_eq!(again.recorder().slo_goodput, rec.slo_goodput);
    }

    /// `policy=turbo` runs the same allocator under controller caps: the
    /// budget invariant holds, requests still complete, and without any
    /// deadline pressure it matches GoodSpeed exactly (no trace ⇒ the
    /// caps never bind).
    #[test]
    fn turbo_runs_traces_and_degrades_to_goodspeed_without_one() {
        let s = Scenario::preset("trace").unwrap();
        let mut sim = AnalyticSim::from_scenario(&s, Policy::Turbo);
        sim.run();
        let summary = sim.recorder().slo_summary().unwrap();
        assert!(summary.completed > 0, "turbo must still serve requests");
        for r in &sim.recorder().rounds {
            let used: usize = r.clients.iter().map(|c| c.s_used).sum();
            assert!(used <= s.capacity, "{used}");
        }
        // Request-free: turbo ≡ goodspeed, wave for wave.
        let mut bare = Scenario::preset("qwen-4c-50").unwrap();
        bare.rounds = 80;
        let mut gs = AnalyticSim::from_scenario(&bare, Policy::GoodSpeed);
        gs.run();
        let mut tb = AnalyticSim::from_scenario(&bare, Policy::Turbo);
        tb.run();
        for (a, b) in gs.recorder().rounds.iter().zip(tb.recorder().rounds.iter()) {
            for (ca, cb) in a.clients.iter().zip(&b.clients) {
                assert_eq!(ca.s_used, cb.s_used);
                assert_eq!(ca.goodput, cb.goodput);
                assert_eq!(ca.next_alloc, cb.next_alloc);
            }
        }
    }

    #[test]
    fn domain_switching_changes_alpha() {
        let mut s = sim(Policy::GoodSpeed, 1, 1);
        s.clients[0].stickiness = 0.0; // always jump
        s.clients[0].max_new_tokens = 2; // finish requests fast
        let a0 = s.clients[0].true_alpha();
        let mut changed = false;
        for _ in 0..50 {
            s.step();
            if (s.clients[0].true_alpha() - a0).abs() > 1e-9 {
                changed = true;
                break;
            }
        }
        assert!(changed, "α must move on domain switches");
    }

    #[test]
    fn member_restriction_touches_only_members() {
        let mut s = sim(Policy::GoodSpeed, 6, 10);
        s.set_members(vec![0, 2, 4]);
        s.core.set_capacity(10);
        for _ in 0..10 {
            s.step_wave();
        }
        let part = s.recorder().participation().to_vec();
        assert!(part[0] > 0 && part[2] > 0 && part[4] > 0, "{part:?}");
        assert_eq!(part[1] + part[3] + part[5], 0, "{part:?}");
        // Non-members' estimators never moved.
        for i in [1usize, 3, 5] {
            assert!((s.estimators().alpha_hat[i] - 0.5).abs() < 1e-12);
        }
        // Member waves respect the shard budget slice.
        for r in &s.recorder().rounds {
            let used: usize = r.clients.iter().map(|c| c.s_used).sum();
            assert!(used <= 10, "{used}");
        }
    }

    #[test]
    fn sharded_run_consumes_budget_and_splits_it() {
        let mut s = Scenario::preset("sharded").unwrap();
        s.rounds = 120;
        s.num_verifiers = 4;
        let out = run_sharded(&s, Policy::GoodSpeed);
        assert_eq!(out.shards.len(), 4);
        // Budget split conserves the global capacity.
        assert!(out.budgets.iter().sum::<usize>() <= s.capacity);
        assert!(out.budgets.iter().all(|&b| b >= 2), "{:?}", out.budgets);
        // The global verification budget is consumed (± one wave/shard).
        let delivered: u64 = out
            .shards
            .iter()
            .map(|sh| sh.recorder().participation().iter().sum::<u64>())
            .sum();
        let total = s.rounds * s.num_clients as u64;
        assert!(delivered >= total && delivered < total + s.num_clients as u64);
        // Every client made progress on exactly one shard.
        let avg = out.avg_goodput();
        assert!(avg.iter().all(|&g| g >= 1.0), "{avg:?}");
        assert!(out.goodput_per_verdict() >= 1.0);
        assert!(out.aggregate_rate() > 0.0);
    }

    /// The chaos mirror: a scheduled shard crash migrates its clients to
    /// the survivor mid-run, recovery repatriates them, the other fault
    /// kinds land in the log, and the per-sweep token series covers the
    /// run — while chaos-free runs keep every new surface empty.
    #[test]
    fn sharded_chaos_crash_migrates_and_recovers() {
        use crate::chaos::{FaultEvent, FaultKind, FaultSchedule};
        let mut s = Scenario::preset("sharded").unwrap();
        s.rounds = 120;
        s.num_verifiers = 2;
        s.chaos = FaultSchedule {
            events: vec![
                FaultEvent {
                    at_wave: 20,
                    kind: FaultKind::ShardCrash { shard: 1, recover_wave: Some(40) },
                },
                FaultEvent {
                    at_wave: 30,
                    kind: FaultKind::Partition { client: 0, heal_wave: 45 },
                },
                FaultEvent { at_wave: 35, kind: FaultKind::DropBurst { client: 1, count: 2 } },
                FaultEvent {
                    at_wave: 35,
                    kind: FaultKind::DuplicateBurst { client: 2, count: 3 },
                },
            ],
        };
        assert!(s.validate().is_ok());
        let out = run_sharded(&s, Policy::GoodSpeed);
        // The budget is consumed despite the outage window.
        let delivered: u64 = out
            .shards
            .iter()
            .map(|sh| sh.recorder().participation().iter().sum::<u64>())
            .sum();
        assert!(delivered >= s.rounds * s.num_clients as u64);
        // Every client kept serving through the crash.
        let avg = out.avg_goodput();
        assert!(avg.iter().all(|&g| g >= 1.0), "{avg:?}");
        // The fault log carries the full lifecycle, once each.
        let kinds: Vec<String> = out.faults().iter().map(|f| f.kind.clone()).collect();
        for k in [
            "shard-crash",
            "shard-recover",
            "partition",
            "partition-heal",
            "drop-burst",
            "duplicate-burst",
        ] {
            assert_eq!(kinds.iter().filter(|x| *x == k).count(), 1, "{k} in {kinds:?}");
        }
        let ttr = out.time_to_recover();
        assert_eq!(ttr.len(), 1);
        assert!(ttr[0] >= 1, "{ttr:?}");
        // The windowed series covers the run: one row per sweep, one
        // column per client slot, with tokens actually accumulated.
        assert!(!out.wave_tokens.is_empty());
        let slots = out.shards[0].clients.len();
        assert!(out.wave_tokens.iter().all(|r| r.len() == slots));
        let toks: u64 = out.wave_tokens.iter().flatten().sum();
        assert!(toks >= delivered, "{toks} tokens over {delivered} verdicts");
        // Chaos-free runs keep the new surfaces empty (pre-chaos path).
        s.chaos = FaultSchedule::default();
        let bare = run_sharded(&s, Policy::GoodSpeed);
        assert!(bare.wave_tokens.is_empty());
        assert!(bare.faults().is_empty() && bare.time_to_recover().is_empty());
    }

    #[test]
    fn sharded_matches_single_shard_goodput_per_verdict() {
        // The shared-core agreement check: tokens per verdict must be in
        // the same ballpark for M = 1 and M = 4 (same α process, same
        // scheduler, proportionally split budget).
        let mut s = Scenario::preset("sharded").unwrap();
        s.rounds = 150;
        s.num_verifiers = 1;
        let one = run_sharded(&s, Policy::GoodSpeed);
        s.num_verifiers = 4;
        let four = run_sharded(&s, Policy::GoodSpeed);
        let (g1, g4) = (one.goodput_per_verdict(), four.goodput_per_verdict());
        assert!(
            (g1 - g4).abs() <= 0.15 * g1,
            "per-verdict goodput drifted: M=1 {g1:.3} vs M=4 {g4:.3}"
        );
    }
}
