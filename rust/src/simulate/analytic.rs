//! Analytic round simulator.
//!
//! Replaces model execution with the acceptance process itself: client i
//! has a *true* time-varying acceptance rate α_i(t) (per-domain base rate,
//! Markov domain switching), per-token acceptance indicators are drawn
//! around it, and rejection sampling runs on those indicators. Everything
//! above the engines — estimators, gradient scheduler, baselines, metrics —
//! is the *same code* as the real stack, so convergence results transfer.
//!
//! Used by the Fig 4 full grid (600 iterations × 3 policies × 2 families ×
//! {4, 8} clients), the β-sweep validating Theorem 1, and the ablations.

use crate::configsys::{Policy, Scenario};
use crate::metrics::recorder::{ClientRoundMetrics, Recorder, RoundRecord};
use crate::sched::baselines::{make_allocator, AllocCaps, Allocator};
use crate::sched::Estimators;
use crate::util::Rng;
use crate::workload::domains::DOMAINS;

/// Base acceptance rate per domain: regular templates are easy for a draft
/// model to imitate, the long-tail domain is not (matches the measured
/// spread of the trained zoo; see EXPERIMENTS.md).
pub fn domain_alpha(domain: &str) -> f64 {
    match domain {
        "alpaca" => 0.85,
        "prompts" => 0.80,
        "cnn" => 0.70,
        "orca" => 0.65,
        "arena" => 0.75,
        "gsm8k" => 0.55,
        "spider" => 0.80,
        "hle" => 0.25,
        _ => 0.5,
    }
}

/// Draft-model quality multiplier (bigger drafts track the target better).
pub fn model_quality(model: &str) -> f64 {
    match model {
        m if m.contains("17b") || m.contains("3b") => 1.1,
        m if m.contains("06b") || m.contains("1b") => 0.9,
        _ => 1.0,
    }
}

/// One simulated client.
#[derive(Clone, Debug)]
pub struct SimClient {
    pub primary_domain: &'static str,
    pub current_domain: &'static str,
    pub quality: f64,
    pub stickiness: f64,
    /// Remaining tokens in the current request.
    pub remaining: usize,
    pub max_new_tokens: usize,
}

impl SimClient {
    /// True per-token acceptance probability right now.
    pub fn true_alpha(&self) -> f64 {
        (domain_alpha(self.current_domain) * self.quality).clamp(0.02, 0.98)
    }
}

/// Simulator configuration (derived from a scenario).
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub capacity: usize,
    pub max_draft: usize,
    pub rounds: u64,
    pub seed: u64,
    /// Std-dev of per-token indicator noise around α (ratio spread).
    pub indicator_noise: f64,
}

impl SimConfig {
    pub fn from_scenario(s: &Scenario) -> SimConfig {
        SimConfig {
            capacity: s.capacity,
            max_draft: s.max_draft,
            rounds: s.rounds,
            seed: s.seed,
            indicator_noise: 0.15,
        }
    }
}

pub struct AnalyticSim {
    pub cfg: SimConfig,
    pub clients: Vec<SimClient>,
    pub estimators: Estimators,
    allocator: Box<dyn Allocator>,
    rng: Rng,
    pub recorder: Recorder,
    alloc: Vec<usize>,
    round: u64,
}

impl AnalyticSim {
    pub fn from_scenario(scenario: &Scenario, policy: Policy) -> AnalyticSim {
        let cfg = SimConfig::from_scenario(scenario);
        let clients = (0..scenario.num_clients)
            .map(|i| {
                let d = DOMAINS
                    .iter()
                    .find(|x| **x == scenario.domain(i))
                    .copied()
                    .expect("domain");
                SimClient {
                    primary_domain: d,
                    current_domain: d,
                    quality: model_quality(scenario.draft_model(i)),
                    stickiness: scenario.domain_stickiness,
                    remaining: scenario.max_new_tokens,
                    max_new_tokens: scenario.max_new_tokens,
                }
            })
            .collect();
        Self::new(cfg, clients, scenario, policy)
    }

    pub fn new(
        cfg: SimConfig,
        clients: Vec<SimClient>,
        scenario: &Scenario,
        policy: Policy,
    ) -> AnalyticSim {
        let n = clients.len();
        let estimators = Estimators::new(n, scenario.eta, scenario.beta);
        let allocator = make_allocator(policy, cfg.seed ^ 0x5eed);
        let initial = (cfg.capacity / n.max(1)).min(cfg.max_draft);
        AnalyticSim {
            rng: Rng::new(cfg.seed ^ 0xAAA),
            alloc: vec![initial; n],
            estimators,
            allocator,
            recorder: Recorder::new(n),
            clients,
            cfg,
            round: 0,
        }
    }

    /// Swap the allocation policy (utility ablations).
    pub fn set_allocator(&mut self, alloc: Box<dyn Allocator>) {
        self.allocator = alloc;
    }

    /// True per-client α vector (ground truth for regret analysis).
    pub fn true_alphas(&self) -> Vec<f64> {
        self.clients.iter().map(|c| c.true_alpha()).collect()
    }

    /// Advance one round; returns realized goodputs.
    pub fn step(&mut self) -> Vec<usize> {
        let n = self.clients.len();
        let mut obs = Vec::with_capacity(n);
        let mut metrics = Vec::with_capacity(n);
        let mut goodputs = Vec::with_capacity(n);
        for i in 0..n {
            let s = self.alloc[i];
            let alpha = self.clients[i].true_alpha();
            // Per-token indicators: clamp(α + noise) — same mean as the
            // real min(1, p/q) ratios; acceptance draws r_j ≤ ratio_j.
            let mut accepted = 0usize;
            let mut ratio_sum = 0.0f64;
            let mut rejected = false;
            for _ in 0..s {
                let ratio =
                    (alpha + self.cfg.indicator_noise * self.rng.normal()).clamp(0.0, 1.0);
                ratio_sum += ratio;
                if !rejected {
                    if self.rng.f64() <= ratio {
                        accepted += 1;
                    } else {
                        rejected = true;
                    }
                }
            }
            let goodput = accepted + 1;
            let mean_ratio = if s == 0 { 1.0 } else { ratio_sum / s as f64 };
            obs.push(Some((mean_ratio, goodput as f64)));
            metrics.push((s, accepted, goodput, mean_ratio));
            goodputs.push(goodput);

            // Request lifecycle + domain switching.
            let c = &mut self.clients[i];
            c.remaining = c.remaining.saturating_sub(goodput);
            if c.remaining == 0 {
                c.remaining = c.max_new_tokens;
                c.current_domain = if self.rng.bool(c.stickiness) {
                    c.primary_domain
                } else {
                    loop {
                        let d = *self.rng.choose(&DOMAINS);
                        if d != c.primary_domain {
                            break d;
                        }
                    }
                };
            }
        }
        self.estimators.update_round(&obs);
        let caps = AllocCaps {
            capacity: self.cfg.capacity,
            max_per_client: vec![self.cfg.max_draft; n],
        };
        self.alloc = self.allocator.allocate(&self.estimators, &caps);
        let clients = metrics
            .iter()
            .enumerate()
            .map(|(i, &(s, accepted, goodput, mean_ratio))| ClientRoundMetrics {
                s_used: s,
                accepted,
                goodput,
                mean_ratio,
                alpha_hat: self.estimators.alpha_hat[i],
                x_beta: self.estimators.x_beta[i],
                next_alloc: self.alloc[i],
            })
            .collect();
        self.recorder.push(RoundRecord {
            round: self.round,
            recv_ns: 0,
            verify_ns: 0,
            send_ns: 0,
            clients,
        });
        self.round += 1;
        goodputs
    }

    /// Run all configured rounds.
    pub fn run(&mut self) {
        for _ in 0..self.cfg.rounds {
            self.step();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::utility::LogUtility;

    fn sim(policy: Policy, clients: usize, rounds: u64) -> AnalyticSim {
        let mut s = Scenario::preset("qwen-8c-150").unwrap();
        s.num_clients = clients;
        s.rounds = rounds;
        AnalyticSim::from_scenario(&s, policy)
    }

    #[test]
    fn runs_fast_and_respects_capacity() {
        let mut s = sim(Policy::GoodSpeed, 8, 300);
        s.run();
        assert_eq!(s.recorder.rounds.len(), 300);
        for r in &s.recorder.rounds {
            let used: usize = r.clients.iter().map(|c| c.s_used).sum();
            assert!(used <= 20);
        }
    }

    #[test]
    fn estimator_tracks_true_alpha() {
        let mut s = sim(Policy::FixedS, 4, 400);
        // Stationary domains for a clean check.
        for c in s.clients.iter_mut() {
            c.stickiness = 1.0;
        }
        s.run();
        for (i, c) in s.clients.iter().enumerate() {
            let est = s.estimators.alpha_hat[i];
            let truth = c.true_alpha();
            assert!(
                (est - truth).abs() < 0.12,
                "client {i}: est {est:.3} vs true {truth:.3}"
            );
        }
    }

    #[test]
    fn goodspeed_beats_baselines_on_log_utility() {
        // The paper's Fig 4 headline: GoodSpeed's U(x̄(T)) tops Fixed-S and
        // Random-S after convergence.
        let u = LogUtility;
        let mut values = Vec::new();
        for p in [Policy::GoodSpeed, Policy::FixedS, Policy::RandomS] {
            let mut s = sim(p, 8, 600);
            s.run();
            values.push(s.recorder.utility_of_avg(&u));
        }
        assert!(
            values[0] > values[1] && values[0] > values[2],
            "U(goodspeed)={:.4} U(fixed)={:.4} U(random)={:.4}",
            values[0],
            values[1],
            values[2]
        );
    }

    #[test]
    fn utility_stabilizes_after_exploration() {
        // Fig 4 shape: early exploration dip, then stabilization — the
        // last-100-rounds utility range must be small.
        let u = LogUtility;
        let mut s = sim(Policy::GoodSpeed, 8, 600);
        let mut curve = Vec::new();
        for _ in 0..600 {
            s.step();
            curve.push(s.recorder.utility_of_avg(&u));
        }
        let tail = &curve[500..];
        let (lo, hi) = tail
            .iter()
            .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &x| (l.min(x), h.max(x)));
        assert!(hi - lo < 0.15, "tail range {}", hi - lo);
        // and the curve must have risen from its early value
        assert!(curve[599] > curve[20]);
    }

    #[test]
    fn heterogeneous_alphas_by_domain() {
        let s = sim(Policy::GoodSpeed, 8, 1);
        let alphas = s.true_alphas();
        let spread = alphas.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            - alphas.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(spread > 0.3, "domains must induce heterogeneity: {alphas:?}");
    }

    #[test]
    fn domain_switching_changes_alpha() {
        let mut s = sim(Policy::GoodSpeed, 1, 1);
        s.clients[0].stickiness = 0.0; // always jump
        s.clients[0].max_new_tokens = 2; // finish requests fast
        let a0 = s.clients[0].true_alpha();
        let mut changed = false;
        for _ in 0..50 {
            s.step();
            if (s.clients[0].true_alpha() - a0).abs() > 1e-9 {
                changed = true;
                break;
            }
        }
        assert!(changed, "α must move on domain switches");
    }
}
