//! Analytic round simulator.
//!
//! Replaces model execution with the acceptance process itself: client i
//! has a *true* time-varying acceptance rate α_i(t) (per-domain base rate,
//! Markov domain switching), per-token acceptance indicators are drawn
//! around it, and rejection sampling runs on those indicators. Everything
//! above the engines — estimators, gradient scheduler, baselines, metrics —
//! is the *same code* as the real stack, so convergence results transfer.
//!
//! Used by the Fig 4 full grid (600 iterations × 3 policies × 2 families ×
//! {4, 8} clients), the β-sweep validating Theorem 1, and the ablations.
//!
//! Both coordinator modes are modeled: `step()` is one sync barrier round,
//! `step_wave()` is one async wave under a stylized virtual-time model
//! (per-client RTT from the scenario links, per-token draft compute, fixed
//! verify cost) so Fig-4-style convergence studies cover sync *and* async
//! wave dynamics without real sleeps.

use crate::configsys::{CoordMode, Policy, Scenario};
use crate::metrics::recorder::{ClientRoundMetrics, Recorder, RoundRecord};
use crate::net::link::{draft_msg_bytes, verdict_msg_bytes, Link};
use crate::sched::baselines::{make_allocator, AllocCaps, Allocator};
use crate::sched::Estimators;
use crate::util::Rng;
use crate::workload::domains::DOMAINS;

/// Base acceptance rate per domain: regular templates are easy for a draft
/// model to imitate, the long-tail domain is not (matches the measured
/// spread of the trained zoo; see EXPERIMENTS.md).
pub fn domain_alpha(domain: &str) -> f64 {
    match domain {
        "alpaca" => 0.85,
        "prompts" => 0.80,
        "cnn" => 0.70,
        "orca" => 0.65,
        "arena" => 0.75,
        "gsm8k" => 0.55,
        "spider" => 0.80,
        "hle" => 0.25,
        _ => 0.5,
    }
}

/// Draft-model quality multiplier (bigger drafts track the target better).
pub fn model_quality(model: &str) -> f64 {
    match model {
        m if m.contains("17b") || m.contains("3b") => 1.1,
        m if m.contains("06b") || m.contains("1b") => 0.9,
        _ => 1.0,
    }
}

/// One simulated client.
#[derive(Clone, Debug)]
pub struct SimClient {
    pub primary_domain: &'static str,
    pub current_domain: &'static str,
    pub quality: f64,
    pub stickiness: f64,
    /// Remaining tokens in the current request.
    pub remaining: usize,
    pub max_new_tokens: usize,
}

impl SimClient {
    /// True per-token acceptance probability right now.
    pub fn true_alpha(&self) -> f64 {
        (domain_alpha(self.current_domain) * self.quality).clamp(0.02, 0.98)
    }
}

/// Simulator configuration (derived from a scenario).
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub capacity: usize,
    pub max_draft: usize,
    pub rounds: u64,
    pub seed: u64,
    /// Std-dev of per-token indicator noise around α (ratio spread).
    pub indicator_noise: f64,
    /// Coordinator discipline to model (sync barrier vs async waves).
    pub mode: CoordMode,
    /// Async batching window, seconds of virtual time.
    pub batch_window_s: f64,
    /// Wave-fill threshold (`0` = all clients).
    pub min_wave_fill: usize,
    /// Virtual-time cost of one batched verify.
    pub verify_s: f64,
    /// Virtual-time draft compute per speculated token.
    pub draft_token_s: f64,
}

impl SimConfig {
    pub fn from_scenario(s: &Scenario) -> SimConfig {
        SimConfig {
            capacity: s.capacity,
            max_draft: s.max_draft,
            rounds: s.rounds,
            seed: s.seed,
            indicator_noise: 0.15,
            mode: s.coord_mode,
            batch_window_s: s.batch_window_us as f64 * 1e-6,
            min_wave_fill: s.effective_wave_fill(),
            verify_s: 2e-3,
            draft_token_s: 2e-4,
        }
    }
}

pub struct AnalyticSim {
    pub cfg: SimConfig,
    pub clients: Vec<SimClient>,
    pub estimators: Estimators,
    allocator: Box<dyn Allocator>,
    rng: Rng,
    pub recorder: Recorder,
    alloc: Vec<usize>,
    round: u64,
    /// Per-client round-trip time (uplink with q payload + verdict
    /// downlink), from the scenario's links.
    rtt_s: Vec<f64>,
    /// Virtual clock (seconds since run start).
    clock: f64,
    /// Virtual time each client's next draft arrives at the server.
    ready_at: Vec<f64>,
}

impl AnalyticSim {
    pub fn from_scenario(scenario: &Scenario, policy: Policy) -> AnalyticSim {
        let cfg = SimConfig::from_scenario(scenario);
        let clients = (0..scenario.num_clients)
            .map(|i| {
                let d = DOMAINS
                    .iter()
                    .find(|x| **x == scenario.domain(i))
                    .copied()
                    .expect("domain");
                SimClient {
                    primary_domain: d,
                    current_domain: d,
                    quality: model_quality(scenario.draft_model(i)),
                    stickiness: scenario.domain_stickiness,
                    remaining: scenario.max_new_tokens,
                    max_new_tokens: scenario.max_new_tokens,
                }
            })
            .collect();
        Self::new(cfg, clients, scenario, policy)
    }

    pub fn new(
        cfg: SimConfig,
        clients: Vec<SimClient>,
        scenario: &Scenario,
        policy: Policy,
    ) -> AnalyticSim {
        let n = clients.len();
        let estimators = Estimators::new(n, scenario.eta, scenario.beta);
        let allocator = make_allocator(policy, cfg.seed ^ 0x5eed);
        let initial = (cfg.capacity / n.max(1)).min(cfg.max_draft);
        // RTT from the scenario links: uplink carries the q payload (the
        // dominant term), downlink the tiny verdict.
        let up_bytes = draft_msg_bytes(64, cfg.max_draft, 256);
        let rtt_s: Vec<f64> = (0..n)
            .map(|i| {
                let l = Link::new(scenario.link(i));
                l.mean_delay(up_bytes).as_secs_f64()
                    + l.mean_delay(verdict_msg_bytes()).as_secs_f64()
            })
            .collect();
        let ready_at: Vec<f64> = (0..n)
            .map(|i| rtt_s[i] + cfg.draft_token_s * initial as f64)
            .collect();
        AnalyticSim {
            rng: Rng::new(cfg.seed ^ 0xAAA),
            alloc: vec![initial; n],
            estimators,
            allocator,
            recorder: Recorder::new(n),
            clients,
            cfg,
            round: 0,
            rtt_s,
            clock: 0.0,
            ready_at,
        }
    }

    /// Virtual seconds elapsed (both modes advance it).
    pub fn virtual_time(&self) -> f64 {
        self.clock
    }

    /// Per-client RTTs the wave model uses (test/inspection hook).
    pub fn rtt_s(&self) -> &[f64] {
        &self.rtt_s
    }

    /// Swap the allocation policy (utility ablations).
    pub fn set_allocator(&mut self, alloc: Box<dyn Allocator>) {
        self.allocator = alloc;
    }

    /// True per-client α vector (ground truth for regret analysis).
    pub fn true_alphas(&self) -> Vec<f64> {
        self.clients.iter().map(|c| c.true_alpha()).collect()
    }

    /// Draw one client's verification outcome: per-token indicators
    /// `clamp(α + noise)` — same mean as the real min(1, p/q) ratios;
    /// acceptance draws r_j ≤ ratio_j. Also advances the client's request
    /// lifecycle + Markov domain switching. Returns
    /// `(s, accepted, goodput, mean_ratio)`.
    fn verify_one(&mut self, i: usize) -> (usize, usize, usize, f64) {
        let s = self.alloc[i];
        let alpha = self.clients[i].true_alpha();
        let mut accepted = 0usize;
        let mut ratio_sum = 0.0f64;
        let mut rejected = false;
        for _ in 0..s {
            let ratio =
                (alpha + self.cfg.indicator_noise * self.rng.normal()).clamp(0.0, 1.0);
            ratio_sum += ratio;
            if !rejected {
                if self.rng.f64() <= ratio {
                    accepted += 1;
                } else {
                    rejected = true;
                }
            }
        }
        let goodput = accepted + 1;
        let mean_ratio = if s == 0 { 1.0 } else { ratio_sum / s as f64 };

        // Request lifecycle + domain switching.
        let c = &mut self.clients[i];
        c.remaining = c.remaining.saturating_sub(goodput);
        if c.remaining == 0 {
            c.remaining = c.max_new_tokens;
            c.current_domain = if self.rng.bool(c.stickiness) {
                c.primary_domain
            } else {
                loop {
                    let d = *self.rng.choose(&DOMAINS);
                    if d != c.primary_domain {
                        break d;
                    }
                }
            };
        }
        (s, accepted, goodput, mean_ratio)
    }

    /// Advance one sync barrier round (all clients); returns realized
    /// goodputs. The RNG stream is identical to the pre-wave simulator.
    pub fn step(&mut self) -> Vec<usize> {
        let n = self.clients.len();
        let mut obs = Vec::with_capacity(n);
        let mut metrics = Vec::with_capacity(n);
        let mut goodputs = Vec::with_capacity(n);
        for i in 0..n {
            let (s, accepted, goodput, mean_ratio) = self.verify_one(i);
            obs.push(Some((mean_ratio, goodput as f64)));
            metrics.push((s, accepted, goodput, mean_ratio));
            goodputs.push(goodput);
        }
        self.estimators.update_round(&obs);
        let caps = AllocCaps::dense(self.cfg.capacity, vec![self.cfg.max_draft; n]);
        self.alloc = self.allocator.allocate(&self.estimators, &caps);
        // Virtual clock: the barrier waits for the slowest client's draft
        // + uplink, then runs one batched verify.
        let recv_s = (0..n)
            .map(|i| self.rtt_s[i] + self.cfg.draft_token_s * metrics[i].0 as f64)
            .fold(0.0f64, f64::max);
        self.clock += recv_s + self.cfg.verify_s;
        let clients = metrics
            .iter()
            .enumerate()
            .map(|(i, &(s, accepted, goodput, mean_ratio))| ClientRoundMetrics {
                client_id: i,
                s_used: s,
                accepted,
                goodput,
                mean_ratio,
                alpha_hat: self.estimators.alpha_hat[i],
                x_beta: self.estimators.x_beta[i],
                next_alloc: self.alloc[i],
            })
            .collect();
        self.recorder.push(RoundRecord {
            round: self.round,
            recv_ns: (recv_s * 1e9) as u64,
            verify_ns: (self.cfg.verify_s * 1e9) as u64,
            send_ns: 0,
            clients,
        });
        self.round += 1;
        goodputs
    }

    /// Advance one async wave: fire on wave-fill or the batching-window
    /// deadline (whichever comes first after the wave's first arrival),
    /// verify the ready subset, reschedule only its members. Returns the
    /// wave's `(client_id, goodput)` pairs.
    pub fn step_wave(&mut self) -> Vec<(usize, usize)> {
        let n = self.clients.len();
        // `min_wave_fill` is pre-resolved by `SimConfig::from_scenario`
        // (Scenario::effective_wave_fill); clamp defensively for
        // hand-built configs that kept the raw `0 = all` sentinel.
        let fill = if self.cfg.min_wave_fill == 0 {
            n
        } else {
            self.cfg.min_wave_fill.min(n)
        };
        // Arrival order of the in-flight drafts.
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| self.ready_at[a].total_cmp(&self.ready_at[b]));
        let t_first = self.ready_at[order[0]];
        let deadline = t_first + self.cfg.batch_window_s;
        let t_fill = self.ready_at[order[fill - 1]];
        // The verification server is single-threaded: a wave can never
        // fire before the previous verify finished (self.clock), however
        // early its drafts arrived — arrivals during the busy period are
        // simply drained into this wave, like the real leader's
        // opportunistic drain.
        let fire_t = (if t_fill <= deadline { t_fill } else { deadline }).max(self.clock);
        let mut members: Vec<usize> =
            order.into_iter().filter(|&i| self.ready_at[i] <= fire_t).collect();
        members.sort_unstable(); // verify in ascending client id

        let mut obs: Vec<(usize, (f64, f64))> = Vec::with_capacity(members.len());
        let mut metrics = Vec::with_capacity(members.len());
        for &i in &members {
            let (s, accepted, goodput, mean_ratio) = self.verify_one(i);
            obs.push((i, (mean_ratio, goodput as f64)));
            metrics.push((i, s, accepted, goodput, mean_ratio));
        }
        self.estimators.update_wave(&obs);
        // Allocate over the wave's live set only; absent clients'
        // in-flight allocations stay reserved out of the budget (same
        // invariant as the real leader: Σ alloc ≤ C at all times).
        let mut live = vec![false; n];
        let mut max_per_client = vec![0usize; n];
        for &i in &members {
            live[i] = true;
            max_per_client[i] = self.cfg.max_draft;
        }
        let reserved: usize =
            (0..n).filter(|&i| !live[i]).map(|i| self.alloc[i]).sum();
        let caps = AllocCaps {
            capacity: self.cfg.capacity.saturating_sub(reserved),
            max_per_client,
            live,
        };
        let wave_alloc = self.allocator.allocate(&self.estimators, &caps);
        let t_done = fire_t + self.cfg.verify_s;
        for &i in &members {
            self.alloc[i] = wave_alloc[i];
            self.ready_at[i] =
                t_done + self.rtt_s[i] + self.cfg.draft_token_s * wave_alloc[i] as f64;
        }
        let clients = metrics
            .iter()
            .map(|&(i, s, accepted, goodput, mean_ratio)| ClientRoundMetrics {
                client_id: i,
                s_used: s,
                accepted,
                goodput,
                mean_ratio,
                alpha_hat: self.estimators.alpha_hat[i],
                x_beta: self.estimators.x_beta[i],
                next_alloc: wave_alloc[i],
            })
            .collect();
        self.recorder.push(RoundRecord {
            round: self.round,
            recv_ns: ((fire_t - self.clock).max(0.0) * 1e9) as u64,
            verify_ns: (self.cfg.verify_s * 1e9) as u64,
            send_ns: 0,
            clients,
        });
        self.clock = t_done;
        self.round += 1;
        metrics.iter().map(|&(i, _, _, g, _)| (i, g)).collect()
    }

    /// Run the configured workload: `rounds` barrier rounds in sync mode,
    /// or waves until the same total verification budget
    /// (`rounds × num_clients` client-rounds) is consumed in async mode.
    pub fn run(&mut self) {
        match self.cfg.mode {
            CoordMode::Sync => {
                for _ in 0..self.cfg.rounds {
                    self.step();
                }
            }
            CoordMode::Async => {
                let budget = self.cfg.rounds * self.clients.len() as u64;
                while self.recorder.participation().iter().sum::<u64>() < budget {
                    self.step_wave();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::utility::LogUtility;

    fn sim(policy: Policy, clients: usize, rounds: u64) -> AnalyticSim {
        let mut s = Scenario::preset("qwen-8c-150").unwrap();
        s.num_clients = clients;
        s.rounds = rounds;
        AnalyticSim::from_scenario(&s, policy)
    }

    #[test]
    fn runs_fast_and_respects_capacity() {
        let mut s = sim(Policy::GoodSpeed, 8, 300);
        s.run();
        assert_eq!(s.recorder.rounds.len(), 300);
        for r in &s.recorder.rounds {
            let used: usize = r.clients.iter().map(|c| c.s_used).sum();
            assert!(used <= 20);
        }
    }

    #[test]
    fn estimator_tracks_true_alpha() {
        let mut s = sim(Policy::FixedS, 4, 400);
        // Stationary domains for a clean check.
        for c in s.clients.iter_mut() {
            c.stickiness = 1.0;
        }
        s.run();
        for (i, c) in s.clients.iter().enumerate() {
            let est = s.estimators.alpha_hat[i];
            let truth = c.true_alpha();
            assert!(
                (est - truth).abs() < 0.12,
                "client {i}: est {est:.3} vs true {truth:.3}"
            );
        }
    }

    #[test]
    fn goodspeed_beats_baselines_on_log_utility() {
        // The paper's Fig 4 headline: GoodSpeed's U(x̄(T)) tops Fixed-S and
        // Random-S after convergence.
        let u = LogUtility;
        let mut values = Vec::new();
        for p in [Policy::GoodSpeed, Policy::FixedS, Policy::RandomS] {
            let mut s = sim(p, 8, 600);
            s.run();
            values.push(s.recorder.utility_of_avg(&u));
        }
        assert!(
            values[0] > values[1] && values[0] > values[2],
            "U(goodspeed)={:.4} U(fixed)={:.4} U(random)={:.4}",
            values[0],
            values[1],
            values[2]
        );
    }

    #[test]
    fn utility_stabilizes_after_exploration() {
        // Fig 4 shape: early exploration dip, then stabilization — the
        // last-100-rounds utility range must be small.
        let u = LogUtility;
        let mut s = sim(Policy::GoodSpeed, 8, 600);
        let mut curve = Vec::new();
        for _ in 0..600 {
            s.step();
            curve.push(s.recorder.utility_of_avg(&u));
        }
        let tail = &curve[500..];
        let (lo, hi) = tail
            .iter()
            .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &x| (l.min(x), h.max(x)));
        assert!(hi - lo < 0.15, "tail range {}", hi - lo);
        // and the curve must have risen from its early value
        assert!(curve[599] > curve[20]);
    }

    #[test]
    fn heterogeneous_alphas_by_domain() {
        let s = sim(Policy::GoodSpeed, 8, 1);
        let alphas = s.true_alphas();
        let spread = alphas.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            - alphas.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(spread > 0.3, "domains must induce heterogeneity: {alphas:?}");
    }

    fn straggler_sim(mode: CoordMode) -> AnalyticSim {
        let mut s = Scenario::preset("straggler").unwrap();
        s.rounds = 400;
        s.coord_mode = mode;
        AnalyticSim::from_scenario(&s, Policy::GoodSpeed)
    }

    #[test]
    fn async_waves_consume_the_same_budget() {
        let mut s = sim(Policy::GoodSpeed, 4, 100);
        s.cfg.mode = CoordMode::Async;
        s.cfg.min_wave_fill = 2;
        s.run();
        let delivered: u64 = s.recorder.participation().iter().sum();
        assert!(delivered >= 400 && delivered < 400 + 4);
        // Waves carry id-ascending subsets and virtual time advances.
        for r in &s.recorder.rounds {
            assert!(!r.clients.is_empty());
            for w in r.clients.windows(2) {
                assert!(w[0].client_id < w[1].client_id);
            }
        }
        assert!(s.virtual_time() > 0.0);
    }

    #[test]
    fn straggler_links_produce_partial_waves() {
        let mut s = straggler_sim(CoordMode::Async);
        assert!(s.rtt_s()[0] > 3.0 * s.rtt_s()[1], "straggler RTT must dominate");
        s.run();
        let n = s.clients.len();
        let partial =
            s.recorder.rounds.iter().filter(|r| r.clients.len() < n).count();
        assert!(partial > 0, "async mode must fire partial waves around the straggler");
        // The fast clients participate in more waves than the straggler.
        let p = s.recorder.participation();
        assert!(p[1] > p[0] && p[2] > p[0] && p[3] > p[0], "{p:?}");
    }

    #[test]
    fn async_recovers_goodput_and_preserves_fairness_under_straggler() {
        // The acceptance-criterion shape, in virtual time: same total
        // verification budget, async finishes sooner ⇒ higher aggregate
        // goodput rate, while per-wave fairness (Jain over accepted
        // tokens per participated wave) stays close to sync.
        use crate::util::stats::jain_index;
        let mut sync = straggler_sim(CoordMode::Sync);
        sync.run();
        let mut asy = straggler_sim(CoordMode::Async);
        asy.run();
        let tokens = |r: &crate::metrics::recorder::Recorder| -> f64 {
            r.cum_goodput().iter().sum()
        };
        let sync_rate = tokens(&sync.recorder) / sync.virtual_time();
        let async_rate = tokens(&asy.recorder) / asy.virtual_time();
        assert!(
            async_rate > sync_rate,
            "async {async_rate:.1} tok/s must beat sync {sync_rate:.1} tok/s"
        );
        let j_sync = jain_index(&sync.recorder.avg_accepted());
        let j_async = jain_index(&asy.recorder.avg_accepted());
        assert!(
            (j_sync - j_async).abs() <= 0.05 * j_sync,
            "fairness drift too large: sync {j_sync:.4} vs async {j_async:.4}"
        );
    }

    #[test]
    fn domain_switching_changes_alpha() {
        let mut s = sim(Policy::GoodSpeed, 1, 1);
        s.clients[0].stickiness = 0.0; // always jump
        s.clients[0].max_new_tokens = 2; // finish requests fast
        let a0 = s.clients[0].true_alpha();
        let mut changed = false;
        for _ in 0..50 {
            s.step();
            if (s.clients[0].true_alpha() - a0).abs() > 1e-9 {
                changed = true;
                break;
            }
        }
        assert!(changed, "α must move on domain switches");
    }
}
