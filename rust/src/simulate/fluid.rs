//! Fluid-limit dynamics (paper Lemma 2 / Theorems 1 & 3).
//!
//! The FSP satisfies `x'(t) = v(t) − x(t)` with
//! `v(t) ∈ argmax_{v ∈ X(t)} Σ_i v_i / x_i(t)` — i.e. every instant the
//! gradient scheduler pushes toward the extreme point of the goodput region
//! maximizing the utility-gradient projection. Euler integration of this
//! ODE is also exactly the Frank–Wolfe algorithm for `max Σ log x_i` over
//! the region, so its fixed point *is* the optimum `x*` of problem (1) —
//! which gives us an independent oracle to verify both the theory and the
//! stochastic system against.

use crate::sched::gradient::{solve_greedy, AllocInput};
use crate::sched::utility::{system_utility, LogUtility};
use crate::spec::expected_goodput;

/// Fluid integrator for fixed true acceptance rates ᾱ.
pub struct FluidSim {
    pub alphas: Vec<f64>,
    pub capacity: usize,
    pub max_draft: usize,
    pub x: Vec<f64>,
}

impl FluidSim {
    pub fn new(alphas: Vec<f64>, capacity: usize, max_draft: usize) -> FluidSim {
        let n = alphas.len();
        FluidSim { alphas, capacity, max_draft, x: vec![1.0; n] }
    }

    /// The drift target v(x): expected goodput of the allocation chosen by
    /// the gradient scheduler at state x.
    pub fn drift_target(&self, x: &[f64]) -> Vec<f64> {
        let weights: Vec<f64> = x.iter().map(|&xi| 1.0 / xi.max(1e-9)).collect();
        let caps = vec![self.max_draft; x.len()];
        let input = AllocInput {
            weights: &weights,
            alphas: &self.alphas,
            capacity: self.capacity,
            max_per_client: &caps,
        };
        let alloc = solve_greedy(&input);
        alloc
            .iter()
            .zip(&self.alphas)
            .map(|(&s, &a)| expected_goodput(a, s))
            .collect()
    }

    /// One Euler step `x ← x + dt (v(x) − x)`.
    pub fn step(&mut self, dt: f64) {
        let v = self.drift_target(&self.x);
        for (xi, vi) in self.x.iter_mut().zip(v) {
            *xi += dt * (vi - *xi);
            *xi = xi.max(1e-9);
        }
    }

    /// Integrate until the drift is tiny or `max_steps` is hit.
    pub fn run_to_fixed_point(&mut self, dt: f64, max_steps: usize) -> usize {
        for step in 0..max_steps {
            let v = self.drift_target(&self.x);
            let drift: f64 = v
                .iter()
                .zip(&self.x)
                .map(|(vi, xi)| (vi - xi).abs())
                .fold(0.0, f64::max);
            if drift < 1e-9 {
                return step;
            }
            for (xi, vi) in self.x.iter_mut().zip(v) {
                *xi += dt * (vi - *xi);
                *xi = xi.max(1e-9);
            }
        }
        max_steps
    }

    pub fn utility(&self) -> f64 {
        system_utility(&LogUtility, &self.x)
    }
}

/// Independent computation of the optimal goodput x* (problem (1)) by
/// long-horizon Frank–Wolfe, plus its utility U(x*).
pub fn optimal_allocation(
    alphas: &[f64],
    capacity: usize,
    max_draft: usize,
) -> (Vec<f64>, f64) {
    let mut sim = FluidSim::new(alphas.to_vec(), capacity, max_draft);
    // Diminishing FW steps: γ_k = 2/(k+2) guarantees convergence for
    // concave objectives over convex hulls.
    for k in 0..20_000usize {
        let v = sim.drift_target(&sim.x.clone());
        let gamma = 2.0 / (k as f64 + 2.0);
        for (xi, vi) in sim.x.iter_mut().zip(v) {
            *xi += gamma * (vi - *xi);
            *xi = xi.max(1e-9);
        }
    }
    let u = sim.utility();
    (sim.x, u)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symmetric_case_has_symmetric_optimum() {
        // N identical clients: x* must split the budget equally.
        let alphas = vec![0.7; 4];
        let (x, _) = optimal_allocation(&alphas, 20, 32);
        // Each gets S=5 → μ = (1−0.7⁶)/0.3
        let expect = expected_goodput(0.7, 5);
        for xi in &x {
            assert!((xi - expect).abs() < 0.05, "x = {x:?} expect {expect}");
        }
    }

    #[test]
    fn fluid_converges_to_optimum_from_anywhere() {
        // Theorem 3: uniform attraction from bounded initial conditions.
        let alphas = vec![0.9, 0.6, 0.3];
        let (x_star, u_star) = optimal_allocation(&alphas, 12, 32);
        for init in [vec![0.1, 5.0, 2.0], vec![3.0, 0.2, 0.2], vec![1.0, 1.0, 1.0]] {
            let mut sim = FluidSim::new(alphas.clone(), 12, 32);
            sim.x = init.clone();
            sim.run_to_fixed_point(0.05, 20_000);
            for (a, b) in sim.x.iter().zip(&x_star) {
                assert!((a - b).abs() < 0.1, "init {init:?}: {:?} vs {x_star:?}", sim.x);
            }
            assert!((sim.utility() - u_star).abs() < 0.05);
        }
    }

    #[test]
    fn utility_nondecreasing_along_fluid_path() {
        // dU/dt ≥ 0 outside the optimum (Lemma 2's Lyapunov argument).
        // Near x* the greedy allocation hops between the hull's integer
        // vertices, so tiny (≲1e-3) Euler dips are expected there — the
        // substantive claims are: no macroscopic descent anywhere, and a
        // strictly higher endpoint.
        let mut sim = FluidSim::new(vec![0.8, 0.5, 0.35, 0.2], 16, 32);
        sim.x = vec![0.5, 2.0, 1.0, 3.0];
        let u0 = sim.utility();
        let mut prev = u0;
        let mut worst_dip = 0.0f64;
        for _ in 0..2000 {
            sim.step(0.02);
            let u = sim.utility();
            worst_dip = worst_dip.max(prev - u);
            prev = u;
        }
        assert!(worst_dip < 1e-3, "macroscopic descent: {worst_dip}");
        assert!(prev > u0 + 0.1, "no ascent: {u0} -> {prev}");
    }

    #[test]
    fn optimum_favors_high_alpha_but_not_exclusively() {
        // Proportional fairness: the α=0.9 client gets more goodput, but
        // the α=0.2 client still gets its ≥1 token/round floor.
        let (x, _) = optimal_allocation(&[0.9, 0.2], 10, 32);
        assert!(x[0] > x[1]);
        assert!(x[1] >= 1.0 - 1e-6, "{x:?}");
    }

    #[test]
    fn boundary_drift_is_positive() {
        // Lemma 2: if x_B ≈ 0 the drift toward B is ≥ μ̲ > 0.
        let sim = FluidSim::new(vec![0.5, 0.5], 8, 32);
        let v = sim.drift_target(&[1e-9, 5.0]);
        assert!(v[0] >= 1.0, "starved client must attract allocation: {v:?}");
    }
}
