//! Fast simulation substrate: the analytic round simulator (no model
//! execution — 10⁴+ rounds/sec for long-horizon convergence studies) and
//! the fluid-limit ODE integrator that validates Theorems 1 and 3.

pub mod analytic;
pub mod fluid;

pub use analytic::{
    run_sharded, run_sharded_with, AnalyticSim, ShardedSimOutcome, SimClient, SimConfig,
};
pub use fluid::{optimal_allocation, FluidSim};
