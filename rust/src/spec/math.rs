//! Goodput formulas from the paper's §III-B.
//!
//! With per-token acceptance probability `α`, the number of accepted tokens
//! from a draft of length `S` is a geometric variable capped at `S`, and the
//! round's expected goodput (accepted + one correction/bonus token) is
//!
//! ```text
//! μ(S, α) = (1 − α^{S+1}) / (1 − α) = 1 + α + α² + … + α^S .
//! ```
//!
//! μ is strictly increasing and strictly concave in `S` with marginal gain
//! Δ(S→S+1) = α^{S+1}; that concavity is what makes the greedy gradient
//! scheduler exact (see `sched::gradient`).

/// Expected goodput μ(S, α) — tokens produced per round for draft length S.
pub fn expected_goodput(alpha: f64, s: usize) -> f64 {
    let alpha = alpha.clamp(0.0, 1.0);
    if (1.0 - alpha) < 1e-12 {
        // lim α→1: 1 + α + … + α^S = S + 1
        return (s + 1) as f64;
    }
    (1.0 - alpha.powi(s as i32 + 1)) / (1.0 - alpha)
}

/// Marginal goodput of extending the draft from `s` to `s+1`: α^{s+1}.
pub fn marginal_gain(alpha: f64, s: usize) -> f64 {
    alpha.clamp(0.0, 1.0).powi(s as i32 + 1)
}

/// Expected goodput of verifying a *full* (arity `a`, depth `d`) candidate
/// tree under per-try acceptance probability `α`, with sequential sibling
/// tries per level: the path advances past a level iff any of the `a`
/// siblings accepts, so the per-level advance probability is
/// `A = 1 − (1 − α)^a` and
///
/// ```text
/// μ_tree(a, d, α) = 1 + A + A² + … + A^d = (1 − A^{d+1}) / (1 − A).
/// ```
///
/// `a = 1` recovers [`expected_goodput`] with `S = d`. Partial trees go
/// through [`DraftTree::expected_goodput`](crate::spec::DraftTree), which
/// sums per-node path probabilities; this closed form is the analytic
/// steady-state model for full profiles.
pub fn expected_tree_goodput(alpha: f64, arity: usize, depth: usize) -> f64 {
    let alpha = alpha.clamp(0.0, 1.0);
    let advance = 1.0 - (1.0 - alpha).powi(arity.max(1) as i32);
    if (1.0 - advance) < 1e-12 {
        return (depth + 1) as f64;
    }
    (1.0 - advance.powi(depth as i32 + 1)) / (1.0 - advance)
}

/// Expected *speedup* of speculative decoding vs autoregressive decoding
/// when verification costs one target forward: μ(S, α) target tokens per
/// round (Leviathan et al. eq. 1; used in the quickstart example report).
pub fn expected_speedup(alpha: f64, s: usize) -> f64 {
    expected_goodput(alpha, s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn closed_form_matches_series() {
        for &alpha in &[0.0f64, 0.1, 0.5, 0.9, 0.99] {
            for s in 0..20usize {
                let series: f64 = (0..=s).map(|j| alpha.powi(j as i32)).sum();
                assert!(
                    (expected_goodput(alpha, s) - series).abs() < 1e-9,
                    "alpha={alpha} s={s}"
                );
            }
        }
    }

    #[test]
    fn limits() {
        // α = 0: only the correction token.
        assert!((expected_goodput(0.0, 10) - 1.0).abs() < 1e-12);
        // α = 1: everything accepted + bonus.
        assert!((expected_goodput(1.0, 10) - 11.0).abs() < 1e-9);
        // S = 0: always exactly one token.
        assert!((expected_goodput(0.7, 0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn prop_monotone_and_concave_in_s() {
        proptest::check("goodput_concave", proptest::default_cases(), |rng| {
            let alpha = rng.f64() * 0.98 + 0.01;
            for s in 0..31usize {
                let a = expected_goodput(alpha, s);
                let b = expected_goodput(alpha, s + 1);
                let c = expected_goodput(alpha, s + 2);
                // Strict monotonicity only while the marginal gain is
                // representable next to μ ≈ 1/(1−α) in f64.
                if marginal_gain(alpha, s) > 1e-12 {
                    assert!(b > a, "monotone alpha={alpha} s={s}");
                } else {
                    assert!(b >= a, "monotone alpha={alpha} s={s}");
                }
                assert!(b - a >= c - b - 1e-12, "concave alpha={alpha} s={s}");
                // marginal gain formula consistency
                assert!((b - a - marginal_gain(alpha, s)).abs() < 1e-9);
            }
        });
    }

    #[test]
    fn prop_monotone_in_alpha() {
        proptest::check("goodput_monotone_alpha", proptest::default_cases(), |rng| {
            let s = rng.below(30) as usize + 1;
            let a1 = rng.f64() * 0.5;
            let a2 = a1 + rng.f64() * 0.4 + 0.01;
            assert!(expected_goodput(a2, s) > expected_goodput(a1, s));
        });
    }

    #[test]
    fn tree_goodput_arity1_matches_chain() {
        for &alpha in &[0.0f64, 0.2, 0.6, 0.9, 1.0] {
            for d in 0..12usize {
                assert!(
                    (expected_tree_goodput(alpha, 1, d) - expected_goodput(alpha, d)).abs()
                        < 1e-9,
                    "alpha={alpha} d={d}"
                );
            }
        }
    }

    #[test]
    fn prop_tree_goodput_monotone_in_arity_and_depth() {
        proptest::check("tree_goodput_monotone", proptest::default_cases(), |rng| {
            let alpha = rng.f64() * 0.9 + 0.05;
            let a = rng.below(4) as usize + 1;
            let d = rng.below(8) as usize + 1;
            // Wider and deeper full trees never lose expected goodput.
            assert!(
                expected_tree_goodput(alpha, a + 1, d) >= expected_tree_goodput(alpha, a, d)
            );
            assert!(
                expected_tree_goodput(alpha, a, d + 1) >= expected_tree_goodput(alpha, a, d)
            );
            // And stay within the perfect-acceptance bound.
            assert!(expected_tree_goodput(alpha, a, d) <= (d + 1) as f64 + 1e-9);
        });
    }

    #[test]
    fn clamps_out_of_range_alpha() {
        assert!((expected_goodput(-0.5, 5) - 1.0).abs() < 1e-12);
        assert!((expected_goodput(1.5, 5) - 6.0).abs() < 1e-9);
    }
}
