//! Speculative-decoding core: goodput math, topologies, and rejection
//! sampling (chain and tree).

pub mod math;
pub mod rejection;
pub mod tree;

pub use math::{expected_goodput, expected_tree_goodput, marginal_gain};
pub use rejection::{verify_client, verify_tree, ClientVerdict, TreeVerdict};
pub use tree::{adaptive_profile, DraftTree};
