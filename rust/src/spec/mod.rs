//! Speculative-decoding core: goodput math and rejection sampling.

pub mod math;
pub mod rejection;

pub use math::{expected_goodput, marginal_gain};
pub use rejection::{verify_client, ClientVerdict};
