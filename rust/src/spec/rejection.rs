//! Rejection-sampling verification (Leviathan et al.; paper §II-A2).
//!
//! The verification engine (XLA or mock) produces, per client:
//! * `ratio[j] = min(1, p_j(s_j) / q_j(s_j))` for each drafted token,
//! * `resid[j] = normalized max(0, p_j − q_j)` residual distributions,
//! * `bonus`  = the target distribution after the full draft.
//!
//! This module turns those into the accepted prefix + correction token:
//! draw `r_j ~ U(0,1)`; accept while `r_j ≤ ratio[j]`; on first rejection at
//! position `m`, sample the correction from `resid[m]`; if all `S` drafts
//! are accepted, sample the bonus token from `bonus`. The output sequence is
//! distributed exactly as the target model (the lossless property —
//! verified statistically in the tests below).

use crate::util::Rng;

/// Per-client verification verdict for one round.
#[derive(Clone, Debug, PartialEq)]
pub struct ClientVerdict {
    /// Number of drafted tokens accepted (m in the paper).
    pub accepted: usize,
    /// The correction (on rejection) or bonus (all accepted) token.
    pub correction: u8,
    /// Realized goodput x_i(t) = accepted + 1 (paper's definition: accepted
    /// tokens plus the correction token from verification).
    pub goodput: usize,
    /// Mean acceptance ratio over ALL drafted tokens — the empirical term
    /// of eq. (3), `(1/S) Σ_j min(1, p_j/q_j)`.
    pub mean_ratio: f64,
}

/// Run rejection sampling for one client.
///
/// `ratios` has length S (the client's draft length this round); `resid` is
/// row-major `[S][vocab]`; `bonus` has length `vocab`.
pub fn verify_client(
    ratios: &[f32],
    resid: &[f32],
    bonus: &[f32],
    vocab: usize,
    rng: &mut Rng,
) -> ClientVerdict {
    let s = ratios.len();
    debug_assert!(resid.len() >= s * vocab, "resid {} < {}", resid.len(), s * vocab);
    debug_assert_eq!(bonus.len(), vocab);

    let mut accepted = 0usize;
    let mut rejected_at: Option<usize> = None;
    for (j, &ratio) in ratios.iter().enumerate() {
        let r = rng.f64();
        if r <= ratio as f64 {
            accepted += 1;
        } else {
            rejected_at = Some(j);
            break;
        }
    }
    let correction = match rejected_at {
        Some(j) => rng.categorical(&resid[j * vocab..(j + 1) * vocab]) as u8,
        None => rng.categorical(bonus) as u8,
    };
    let mean_ratio = if s == 0 {
        // Degenerate S=0 rounds contribute a neutral estimate.
        1.0
    } else {
        ratios.iter().map(|&r| r as f64).sum::<f64>() / s as f64
    };
    ClientVerdict { accepted, correction, goodput: accepted + 1, mean_ratio }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn accepts_all_when_ratios_one() {
        let mut rng = Rng::new(0);
        let vocab = 4;
        let ratios = vec![1.0f32; 5];
        let resid = vec![0.25f32; 5 * vocab];
        let bonus = vec![0.0, 0.0, 1.0, 0.0];
        let v = verify_client(&ratios, &resid, &bonus, vocab, &mut rng);
        assert_eq!(v.accepted, 5);
        assert_eq!(v.correction, 2); // bonus is a point mass on 2
        assert_eq!(v.goodput, 6);
        assert!((v.mean_ratio - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_all_when_ratios_zero() {
        let mut rng = Rng::new(1);
        let vocab = 4;
        let ratios = vec![0.0f32; 3];
        let mut resid = vec![0.0f32; 3 * vocab];
        resid[1] = 1.0; // first row point mass on token 1
        let bonus = vec![0.25f32; vocab];
        let v = verify_client(&ratios, &resid, &bonus, vocab, &mut rng);
        assert_eq!(v.accepted, 0);
        assert_eq!(v.correction, 1);
        assert_eq!(v.goodput, 1);
        assert!((v.mean_ratio - 0.0).abs() < 1e-12);
    }

    #[test]
    fn empty_draft_samples_bonus() {
        let mut rng = Rng::new(2);
        let bonus = vec![0.0, 1.0, 0.0, 0.0];
        let v = verify_client(&[], &[], &bonus, 4, &mut rng);
        assert_eq!(v.accepted, 0);
        assert_eq!(v.correction, 1);
        assert_eq!(v.goodput, 1);
    }

    #[test]
    fn acceptance_count_matches_geometric_law() {
        // With constant ratio α the accepted count is min(Geom(1-α), S).
        let alpha = 0.7f32;
        let s = 6;
        let vocab = 2;
        let ratios = vec![alpha; s];
        let resid = vec![0.5f32; s * vocab];
        let bonus = vec![0.5f32; vocab];
        let mut rng = Rng::new(3);
        let n = 200_000;
        let mut total = 0usize;
        for _ in 0..n {
            total += verify_client(&ratios, &resid, &bonus, vocab, &mut rng).accepted;
        }
        let mean = total as f64 / n as f64;
        // E[min(Geom, S)] = α(1-α^S)/(1-α)
        let a = alpha as f64;
        let expect = a * (1.0 - a.powi(s as i32)) / (1.0 - a);
        assert!((mean - expect).abs() < 0.02, "mean {mean} expect {expect}");
    }

    /// The lossless property: speculative output ≡ target distribution.
    ///
    /// Build explicit p and q over a small vocab, compute exact ratios and
    /// residuals (as the verify kernel does), run the full accept/reject +
    /// correction pipeline, and χ²-test the *first output token* against p.
    #[test]
    fn output_distribution_equals_target() {
        let p = [0.5f32, 0.3, 0.15, 0.05];
        let q = [0.25f32, 0.25, 0.25, 0.25];
        let vocab = 4;
        let ratio_of = |tok: usize| (p[tok] / q[tok]).min(1.0);
        let mut resid = [0.0f32; 4];
        let mut rsum = 0.0;
        for t in 0..vocab {
            resid[t] = (p[t] - q[t]).max(0.0);
            rsum += resid[t];
        }
        for r in resid.iter_mut() {
            *r /= rsum;
        }
        let mut rng = Rng::new(4);
        let n = 300_000;
        let mut counts = [0u64; 4];
        for _ in 0..n {
            // draft one token from q
            let d = rng.categorical(&q);
            let ratios = [ratio_of(d)];
            let resid_rows = resid;
            let bonus = [0.25f32; 4]; // irrelevant: S=1 accept path emits d
            let v = verify_client(&ratios, &resid_rows, &bonus, vocab, &mut rng);
            let out = if v.accepted == 1 { d } else { v.correction as usize };
            counts[out] += 1;
        }
        for t in 0..vocab {
            let freq = counts[t] as f64 / n as f64;
            assert!(
                (freq - p[t] as f64).abs() < 0.005,
                "token {t}: freq {freq} vs p {}",
                p[t]
            );
        }
    }

    #[test]
    fn prop_verdict_invariants() {
        proptest::check("verdict_invariants", proptest::default_cases(), |rng| {
            let vocab = 8;
            let s = rng.below(12) as usize;
            let ratios: Vec<f32> = (0..s).map(|_| rng.f32()).collect();
            let resid: Vec<f32> = (0..s * vocab).map(|_| rng.f32()).collect();
            let bonus: Vec<f32> = (0..vocab).map(|_| rng.f32() + 1e-3).collect();
            let v = verify_client(&ratios, &resid, &bonus, vocab, rng);
            assert!(v.accepted <= s);
            assert_eq!(v.goodput, v.accepted + 1);
            assert!((v.correction as usize) < vocab);
            assert!((0.0..=1.0 + 1e-9).contains(&v.mean_ratio));
        });
    }
}
