//! Rejection-sampling verification (Leviathan et al.; paper §II-A2).
//!
//! The verification engine (XLA or mock) produces, per client:
//! * `ratio[j] = min(1, p_j(s_j) / q_j(s_j))` for each drafted token,
//! * `resid[j] = normalized max(0, p_j − q_j)` residual distributions,
//! * `bonus`  = the target distribution after the full draft.
//!
//! This module turns those into the accepted prefix + correction token:
//! draw `r_j ~ U(0,1)`; accept while `r_j ≤ ratio[j]`; on first rejection at
//! position `m`, sample the correction from `resid[m]`; if all `S` drafts
//! are accepted, sample the bonus token from `bonus`. The output sequence is
//! distributed exactly as the target model (the lossless property —
//! verified statistically in the tests below).

//!
//! [`verify_tree`] generalizes the same math to a [`DraftTree`]: walk from
//! the root, trying each level's sibling candidates sequentially with
//! recursive-rejection residuals (lossless for i.i.d. proposals), descend
//! on the first accepted sibling, and sample the correction from the final
//! residual at the first off-path rejection — or the leaf's phantom bonus
//! row when the whole path is accepted. A chain is the arity-1 tree and
//! produces bit-identical RNG draws to [`verify_client`].

use crate::spec::tree::DraftTree;
use crate::util::Rng;

/// Per-client verification verdict for one round.
#[derive(Clone, Debug, PartialEq)]
pub struct ClientVerdict {
    /// Number of drafted tokens accepted (m in the paper).
    pub accepted: usize,
    /// The correction (on rejection) or bonus (all accepted) token.
    pub correction: u8,
    /// Realized goodput x_i(t) = accepted + 1 (paper's definition: accepted
    /// tokens plus the correction token from verification).
    pub goodput: usize,
    /// Mean acceptance ratio over ALL drafted tokens — the empirical term
    /// of eq. (3), `(1/S) Σ_j min(1, p_j/q_j)`.
    pub mean_ratio: f64,
}

/// Run rejection sampling for one client.
///
/// `ratios` has length S (the client's draft length this round); `resid` is
/// row-major `[S][vocab]`; `bonus` has length `vocab`.
pub fn verify_client(
    ratios: &[f32],
    resid: &[f32],
    bonus: &[f32],
    vocab: usize,
    rng: &mut Rng,
) -> ClientVerdict {
    let s = ratios.len();
    debug_assert!(resid.len() >= s * vocab, "resid {} < {}", resid.len(), s * vocab);
    debug_assert_eq!(bonus.len(), vocab);

    let mut accepted = 0usize;
    let mut rejected_at: Option<usize> = None;
    for (j, &ratio) in ratios.iter().enumerate() {
        let r = rng.f64();
        if r <= ratio as f64 {
            accepted += 1;
        } else {
            rejected_at = Some(j);
            break;
        }
    }
    let correction = match rejected_at {
        Some(j) => rng.categorical(&resid[j * vocab..(j + 1) * vocab]) as u8,
        None => rng.categorical(bonus) as u8,
    };
    let mean_ratio = if s == 0 {
        // Degenerate S=0 rounds contribute a neutral estimate.
        1.0
    } else {
        ratios.iter().map(|&r| r as f64).sum::<f64>() / s as f64
    };
    ClientVerdict { accepted, correction, goodput: accepted + 1, mean_ratio }
}

/// Verdict of one tree verification.
#[derive(Clone, Debug, PartialEq)]
pub struct TreeVerdict {
    /// Accepted node ids along the root path, in root → leaf order.
    pub path: Vec<usize>,
    /// The correction (off-path rejection) or bonus (leaf reached) token.
    pub correction: u8,
    /// Realized goodput: accepted depth + 1.
    pub goodput: usize,
    /// Mean acceptance ratio over ALL drafted nodes (eq. 3 per-node term).
    pub mean_ratio: f64,
}

/// Run tree rejection sampling for one client (the tree generalization of
/// [`verify_client`]; lossless — the output path + correction is
/// distributed exactly as target-model sampling).
///
/// At each level the current node's children are tried in node order.
/// The first child uses the engine ratio `min(1, p/q)`. After `j`
/// rejections the (normalized) leftover target is `resid_j` — the
/// engine's residual row for `j = 1`, then `norm((resid_j − q)₊)` — and
/// child `j+1` (an i.i.d. proposal from the same `q`) accepts with
/// `min(1, resid_j(tok)/q(tok))`: the recursive-rejection scheme whose
/// per-level acceptance telescopes exactly to the target distribution.
/// If every child rejects, the correction is sampled from the final
/// residual; if the path reaches a leaf, the bonus is sampled from the
/// leaf's phantom row (all-zero q ⇒ residual ≡ the target after the
/// path). See `spec/tree.rs` for the row-layout contract.
///
/// * `tokens` — drafted token per node (`tree.len()` entries);
/// * `ratios` — engine `min(1, p/q)` per node (`≥ tree.len()` entries);
/// * `resid`  — row-major `[rows × vocab]` residuals covering
///   `tree.rows_needed()` rows (real nodes then phantom leaf rows);
/// * `q`      — row-major `[tree.len() × vocab]` proposal distributions.
pub fn verify_tree(
    tree: &DraftTree,
    tokens: &[u8],
    ratios: &[f32],
    resid: &[f32],
    q: &[f32],
    vocab: usize,
    rng: &mut Rng,
) -> TreeVerdict {
    let n = tree.len();
    let v = vocab;
    debug_assert!(tokens.len() >= n);
    debug_assert!(ratios.len() >= n);
    debug_assert!(resid.len() >= tree.rows_needed() * v);
    debug_assert!(q.len() >= n * v);
    let mean_ratio = if n == 0 {
        1.0
    } else {
        ratios[..n].iter().map(|&r| r as f64).sum::<f64>() / n as f64
    };

    let mut path: Vec<usize> = Vec::new();
    let mut cur: Option<usize> = None;
    loop {
        let kids: &[usize] = match cur {
            None => tree.root_children(),
            Some(i) => tree.children(i),
        };
        if kids.is_empty() {
            // Whole path accepted: bonus from the phantom row after `cur`
            // (row 0 for the empty tree — exactly the chain's S = 0 case).
            let row = match cur {
                None => 0,
                Some(leaf) => tree.bonus_row(leaf),
            };
            let correction = rng.categorical(&resid[row * v..(row + 1) * v]) as u8;
            return TreeVerdict { goodput: path.len() + 1, path, correction, mean_ratio };
        }
        // Sequential sibling tries with recursive-rejection residuals.
        let mut residual: Vec<f32> = Vec::new();
        let mut descended: Option<usize> = None;
        for (j, &c) in kids.iter().enumerate() {
            let accept_p = if j == 0 {
                ratios[c] as f64
            } else {
                let tok = tokens[c] as usize;
                let qt = q[c * v + tok].max(1e-9) as f64;
                (residual[tok] as f64 / qt).min(1.0)
            };
            if rng.f64() <= accept_p {
                descended = Some(c);
                break;
            }
            if j == 0 {
                residual = resid[c * v..(c + 1) * v].to_vec();
            } else {
                let qr = &q[c * v..(c + 1) * v];
                let mut s = 0.0f32;
                for t in 0..v {
                    let d = (residual[t] - qr[t]).max(0.0);
                    residual[t] = d;
                    s += d;
                }
                if s > 1e-9 {
                    for x in residual.iter_mut() {
                        *x /= s;
                    }
                }
                // s ≈ 0 means this try accepts almost surely; the uniform
                // fallback inside `categorical` covers the measure-zero
                // remainder.
            }
        }
        match descended {
            Some(c) => {
                path.push(c);
                cur = Some(c);
            }
            None => {
                let correction = rng.categorical(&residual) as u8;
                return TreeVerdict { goodput: path.len() + 1, path, correction, mean_ratio };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::tree::NO_PARENT;
    use crate::util::proptest;

    #[test]
    fn accepts_all_when_ratios_one() {
        let mut rng = Rng::new(0);
        let vocab = 4;
        let ratios = vec![1.0f32; 5];
        let resid = vec![0.25f32; 5 * vocab];
        let bonus = vec![0.0, 0.0, 1.0, 0.0];
        let v = verify_client(&ratios, &resid, &bonus, vocab, &mut rng);
        assert_eq!(v.accepted, 5);
        assert_eq!(v.correction, 2); // bonus is a point mass on 2
        assert_eq!(v.goodput, 6);
        assert!((v.mean_ratio - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_all_when_ratios_zero() {
        let mut rng = Rng::new(1);
        let vocab = 4;
        let ratios = vec![0.0f32; 3];
        let mut resid = vec![0.0f32; 3 * vocab];
        resid[1] = 1.0; // first row point mass on token 1
        let bonus = vec![0.25f32; vocab];
        let v = verify_client(&ratios, &resid, &bonus, vocab, &mut rng);
        assert_eq!(v.accepted, 0);
        assert_eq!(v.correction, 1);
        assert_eq!(v.goodput, 1);
        assert!((v.mean_ratio - 0.0).abs() < 1e-12);
    }

    #[test]
    fn empty_draft_samples_bonus() {
        let mut rng = Rng::new(2);
        let bonus = vec![0.0, 1.0, 0.0, 0.0];
        let v = verify_client(&[], &[], &bonus, 4, &mut rng);
        assert_eq!(v.accepted, 0);
        assert_eq!(v.correction, 1);
        assert_eq!(v.goodput, 1);
    }

    #[test]
    fn acceptance_count_matches_geometric_law() {
        // With constant ratio α the accepted count is min(Geom(1-α), S).
        let alpha = 0.7f32;
        let s = 6;
        let vocab = 2;
        let ratios = vec![alpha; s];
        let resid = vec![0.5f32; s * vocab];
        let bonus = vec![0.5f32; vocab];
        let mut rng = Rng::new(3);
        let n = 200_000;
        let mut total = 0usize;
        for _ in 0..n {
            total += verify_client(&ratios, &resid, &bonus, vocab, &mut rng).accepted;
        }
        let mean = total as f64 / n as f64;
        // E[min(Geom, S)] = α(1-α^S)/(1-α)
        let a = alpha as f64;
        let expect = a * (1.0 - a.powi(s as i32)) / (1.0 - a);
        assert!((mean - expect).abs() < 0.02, "mean {mean} expect {expect}");
    }

    /// The lossless property: speculative output ≡ target distribution.
    ///
    /// Build explicit p and q over a small vocab, compute exact ratios and
    /// residuals (as the verify kernel does), run the full accept/reject +
    /// correction pipeline, and χ²-test the *first output token* against p.
    #[test]
    fn output_distribution_equals_target() {
        let p = [0.5f32, 0.3, 0.15, 0.05];
        let q = [0.25f32, 0.25, 0.25, 0.25];
        let vocab = 4;
        let ratio_of = |tok: usize| (p[tok] / q[tok]).min(1.0);
        let mut resid = [0.0f32; 4];
        let mut rsum = 0.0;
        for t in 0..vocab {
            resid[t] = (p[t] - q[t]).max(0.0);
            rsum += resid[t];
        }
        for r in resid.iter_mut() {
            *r /= rsum;
        }
        let mut rng = Rng::new(4);
        let n = 300_000;
        let mut counts = [0u64; 4];
        for _ in 0..n {
            // draft one token from q
            let d = rng.categorical(&q);
            let ratios = [ratio_of(d)];
            let resid_rows = resid;
            let bonus = [0.25f32; 4]; // irrelevant: S=1 accept path emits d
            let v = verify_client(&ratios, &resid_rows, &bonus, vocab, &mut rng);
            let out = if v.accepted == 1 { d } else { v.correction as usize };
            counts[out] += 1;
        }
        for t in 0..vocab {
            let freq = counts[t] as f64 / n as f64;
            assert!(
                (freq - p[t] as f64).abs() < 0.005,
                "token {t}: freq {freq} vs p {}",
                p[t]
            );
        }
    }

    /// Chain ≡ arity-1 tree, bit for bit: identical RNG draw sequences ⇒
    /// identical accepted counts and corrections on every case.
    #[test]
    fn prop_chain_equals_arity1_tree_bit_for_bit() {
        proptest::check("chain_tree_equivalence", proptest::default_cases(), |rng| {
            let vocab = 8;
            let s = rng.below(10) as usize;
            let ratios: Vec<f32> = (0..s).map(|_| rng.f32()).collect();
            // Real-node residual rows plus the phantom bonus row at `s`
            // (the chain layout for S < K).
            let resid: Vec<f32> =
                (0..(s + 1) * vocab).map(|_| rng.f32() + 1e-3).collect();
            let bonus = &resid[s * vocab..(s + 1) * vocab];
            let tokens: Vec<u8> = (0..s).map(|_| rng.below(vocab as u64) as u8).collect();
            let q: Vec<f32> = (0..s * vocab).map(|_| rng.f32() + 1e-3).collect();
            let seed = rng.next_u64();
            let mut rng_a = Rng::new(seed);
            let mut rng_b = Rng::new(seed);
            let chain = verify_client(&ratios, &resid, bonus, vocab, &mut rng_a);
            let tree = DraftTree::chain(s);
            let tv = verify_tree(&tree, &tokens, &ratios, &resid, &q, vocab, &mut rng_b);
            assert_eq!(tv.path.len(), chain.accepted);
            assert_eq!(tv.path, (0..chain.accepted).collect::<Vec<_>>());
            assert_eq!(tv.correction, chain.correction);
            assert_eq!(tv.goodput, chain.goodput);
            assert!((tv.mean_ratio - chain.mean_ratio).abs() < 1e-12);
            // The two consumed exactly the same RNG stream.
            assert_eq!(rng_a.next_u64(), rng_b.next_u64());
        });
    }

    /// The tree lossless property: with two sibling candidates drawn
    /// i.i.d. from q and verified by the sequential-residual scheme, the
    /// *first output token* is still distributed exactly as the target p.
    #[test]
    fn tree_output_distribution_equals_target() {
        let p = [0.5f32, 0.3, 0.15, 0.05];
        let q = [0.25f32, 0.25, 0.25, 0.25];
        let vocab = 4;
        let ratio_of = |tok: usize| (p[tok] / q[tok]).min(1.0);
        let mut resid_row = [0.0f32; 4];
        let mut rsum = 0.0;
        for t in 0..vocab {
            resid_row[t] = (p[t] - q[t]).max(0.0);
            rsum += resid_row[t];
        }
        for r in resid_row.iter_mut() {
            *r /= rsum;
        }
        // Depth-1 arity-2 tree: two root children (leaves at rows 2, 3).
        let tree = DraftTree::from_parents(vec![NO_PARENT; 2]).unwrap();
        assert_eq!(tree.rows_needed(), 4);
        let mut rng = Rng::new(40);
        let n = 300_000;
        let mut counts = [0u64; 4];
        for _ in 0..n {
            let t1 = rng.categorical(&q) as u8;
            let t2 = rng.categorical(&q) as u8;
            let tokens = [t1, t2];
            let ratios = [ratio_of(t1 as usize), ratio_of(t2 as usize)];
            // Rows 0–1: the siblings' (identical) residual rows; rows 2–3:
            // phantom bonus rows (irrelevant for the first output token).
            let mut resid = Vec::with_capacity(4 * vocab);
            resid.extend_from_slice(&resid_row);
            resid.extend_from_slice(&resid_row);
            resid.extend_from_slice(&[0.25f32; 4]);
            resid.extend_from_slice(&[0.25f32; 4]);
            let mut qrows = Vec::with_capacity(2 * vocab);
            qrows.extend_from_slice(&q);
            qrows.extend_from_slice(&q);
            let tv = verify_tree(&tree, &tokens, &ratios, &resid, &qrows, vocab, &mut rng);
            let out = match tv.path.first() {
                Some(&node) => tokens[node] as usize,
                None => tv.correction as usize,
            };
            counts[out] += 1;
        }
        for t in 0..vocab {
            let freq = counts[t] as f64 / n as f64;
            assert!(
                (freq - p[t] as f64).abs() < 0.005,
                "token {t}: freq {freq} vs p {}",
                p[t]
            );
        }
    }

    /// Same lossless check as a χ² statistic (k − 1 = 3 dof; 16.27 is the
    /// 0.1% critical value — a deterministic seed keeps this stable).
    #[test]
    fn tree_output_chi_square_within_critical_value() {
        let p = [0.4f32, 0.3, 0.2, 0.1];
        let q = [0.1f32, 0.2, 0.3, 0.4];
        let vocab = 4;
        let ratio_of = |tok: usize| (p[tok] / q[tok]).min(1.0);
        let mut resid_row = [0.0f32; 4];
        let mut rsum = 0.0;
        for t in 0..vocab {
            resid_row[t] = (p[t] - q[t]).max(0.0);
            rsum += resid_row[t];
        }
        for r in resid_row.iter_mut() {
            *r /= rsum;
        }
        let tree = DraftTree::from_parents(vec![NO_PARENT; 3]).unwrap();
        let mut rng = Rng::new(41);
        let n = 200_000usize;
        let mut counts = [0u64; 4];
        for _ in 0..n {
            let tokens: Vec<u8> = (0..3).map(|_| rng.categorical(&q) as u8).collect();
            let ratios: Vec<f32> = tokens.iter().map(|&t| ratio_of(t as usize)).collect();
            let mut resid = Vec::with_capacity(6 * vocab);
            for _ in 0..3 {
                resid.extend_from_slice(&resid_row);
            }
            for _ in 0..3 {
                resid.extend_from_slice(&[0.25f32; 4]); // phantom rows
            }
            let mut qrows = Vec::with_capacity(3 * vocab);
            for _ in 0..3 {
                qrows.extend_from_slice(&q);
            }
            let tv = verify_tree(&tree, &tokens, &ratios, &resid, &qrows, vocab, &mut rng);
            let out = match tv.path.first() {
                Some(&node) => tokens[node] as usize,
                None => tv.correction as usize,
            };
            counts[out] += 1;
        }
        let chi2: f64 = (0..vocab)
            .map(|t| {
                let expect = p[t] as f64 * n as f64;
                let d = counts[t] as f64 - expect;
                d * d / expect
            })
            .sum();
        assert!(chi2 < 16.27, "chi2 {chi2} (counts {counts:?})");
    }

    #[test]
    fn prop_tree_verdict_invariants() {
        proptest::check("tree_verdict_invariants", proptest::default_cases(), |rng| {
            let vocab = 8;
            let arity = rng.below(3) as usize + 1;
            let depth = rng.below(4) as usize + 1;
            let budget = rng.below(10) as usize;
            let tree = DraftTree::shaped(arity, depth, budget, 24, 16);
            let n = tree.len();
            let rows = tree.rows_needed();
            let tokens: Vec<u8> = (0..n).map(|_| rng.below(vocab as u64) as u8).collect();
            let ratios: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            let resid: Vec<f32> = (0..rows * vocab).map(|_| rng.f32() + 1e-3).collect();
            let q: Vec<f32> = (0..n * vocab).map(|_| rng.f32() + 1e-3).collect();
            let tv = verify_tree(&tree, &tokens, &ratios, &resid, &q, vocab, rng);
            assert_eq!(tv.goodput, tv.path.len() + 1);
            assert!(tv.path.len() <= tree.max_depth());
            assert!((tv.correction as usize) < vocab);
            assert!((0.0..=1.0 + 1e-9).contains(&tv.mean_ratio));
            // The path is a root-descending parent chain.
            for (d, &node) in tv.path.iter().enumerate() {
                assert_eq!(tree.depth(node), d + 1);
                let parent = tree.parent_of(node);
                if d == 0 {
                    assert_eq!(parent, None);
                } else {
                    assert_eq!(parent, Some(tv.path[d - 1]));
                }
            }
        });
    }

    #[test]
    fn prop_verdict_invariants() {
        proptest::check("verdict_invariants", proptest::default_cases(), |rng| {
            let vocab = 8;
            let s = rng.below(12) as usize;
            let ratios: Vec<f32> = (0..s).map(|_| rng.f32()).collect();
            let resid: Vec<f32> = (0..s * vocab).map(|_| rng.f32()).collect();
            let bonus: Vec<f32> = (0..vocab).map(|_| rng.f32() + 1e-3).collect();
            let v = verify_client(&ratios, &resid, &bonus, vocab, rng);
            assert!(v.accepted <= s);
            assert_eq!(v.goodput, v.accepted + 1);
            assert!((v.correction as usize) < vocab);
            assert!((0.0..=1.0 + 1e-9).contains(&v.mean_ratio));
        });
    }
}
