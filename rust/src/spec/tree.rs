//! `DraftTree` — node-indexed speculation topology.
//!
//! A draft is a tree of candidate tokens rooted at the current prefix:
//! node `i` holds one drafted token whose context is the prefix plus the
//! tokens along `i`'s ancestor path. A linear chain is the degenerate
//! arity-1 tree, so every layer that consumes a `DraftTree` (drafting,
//! wire, batching, verification, scheduling, simulation) handles both
//! shapes through one abstraction — and chain-mode runs stay bit-identical
//! to the pre-tree stack.
//!
//! Topology is a parent-index array: `parent[i] < i` (topological order)
//! or [`NO_PARENT`] for children of the root. Sibling order is node-index
//! order; verification tries siblings sequentially in that order (the
//! recursive-rejection residual scheme in
//! [`verify_tree`](crate::spec::rejection::verify_tree)), so the drafting
//! and verifying sides agree on the RNG/order contract by construction.
//!
//! **Row layout contract** (shared with `coordinator/batcher.rs` and the
//! verify engines): the `k` engine rows of one client hold the `n` real
//! nodes at rows `0..n`, then one *phantom* row per leaf (ascending leaf
//! order, rows `n..n+L`) whose q-row is all-zero — its residual therefore
//! reduces to the raw target distribution after that leaf, i.e. the
//! leaf's bonus distribution. An empty tree keeps the phantom at row 0.
//! This is the same trick the chain already used (the all-zero q row at
//! `j = S`), generalized to one row per leaf.

use anyhow::{anyhow, Result};

/// Parent sentinel for children of the root (the current prefix).
pub const NO_PARENT: u8 = u8::MAX;

/// A speculation topology (tokens live outside, indexed by node id).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DraftTree {
    /// `parent[i]` is the parent node of `i` (`< i`), or [`NO_PARENT`].
    parent: Vec<u8>,
    /// `children[0]` = root's children; `children[i + 1]` = node `i`'s,
    /// each in ascending node order (== sibling try order).
    children: Vec<Vec<usize>>,
    /// 1-based depth per node (root children have depth 1).
    depth: Vec<usize>,
    /// Engine row of each leaf's phantom bonus row (`u32::MAX` internal).
    bonus_row: Vec<u32>,
    num_leaves: usize,
    max_depth: usize,
}

impl DraftTree {
    /// The degenerate arity-1 tree: node `i`'s parent is `i − 1`.
    pub fn chain(s: usize) -> DraftTree {
        let parent: Vec<u8> =
            (0..s).map(|i| if i == 0 { NO_PARENT } else { (i - 1) as u8 }).collect();
        DraftTree::from_parents(parent).expect("chain is always valid")
    }

    /// Build from a parent-index array (the wire form). Requires
    /// topological order: `parent[i] < i` or `NO_PARENT`.
    pub fn from_parents(parent: Vec<u8>) -> Result<DraftTree> {
        let n = parent.len();
        if n > NO_PARENT as usize {
            return Err(anyhow!("tree too large: {n} nodes (max {})", NO_PARENT));
        }
        let mut children: Vec<Vec<usize>> = vec![Vec::new(); n + 1];
        for (i, &p) in parent.iter().enumerate() {
            if p == NO_PARENT {
                children[0].push(i);
            } else if (p as usize) < i {
                children[p as usize + 1].push(i);
            } else {
                return Err(anyhow!("node {i}: parent {p} violates topological order"));
            }
        }
        let mut depth = vec![0usize; n];
        let mut max_depth = 0usize;
        for (i, &p) in parent.iter().enumerate() {
            depth[i] = if p == NO_PARENT { 1 } else { depth[p as usize] + 1 };
            max_depth = max_depth.max(depth[i]);
        }
        let mut bonus_row = vec![u32::MAX; n];
        let mut num_leaves = 0usize;
        for i in 0..n {
            if children[i + 1].is_empty() {
                bonus_row[i] = (n + num_leaves) as u32;
                num_leaves += 1;
            }
        }
        Ok(DraftTree { parent, children, depth, bonus_row, num_leaves, max_depth })
    }

    /// Deterministic shape policy: spend up to `budget` nodes on an
    /// (`arity`, `depth`) profile — levels `1..=depth` give every frontier
    /// node `arity` children (leftmost-parent first), deeper levels
    /// continue as a chain tail so a generous budget is still spent —
    /// subject to `max_rows` engine rows (nodes + phantom leaf rows) and
    /// `max_depth` context room.
    pub fn shaped(
        arity: usize,
        depth: usize,
        budget: usize,
        max_rows: usize,
        max_depth: usize,
    ) -> DraftTree {
        let arity = arity.max(1);
        let depth = depth.max(1);
        if budget == 0 || max_depth == 0 || max_rows < 2 {
            return DraftTree::chain(0);
        }
        let mut parent: Vec<u8> = Vec::new();
        let mut nodes = 0usize;
        let mut leaves = 0usize;
        // `None` = the root; `Some(i)` = node i.
        let mut frontier: Vec<Option<usize>> = vec![None];
        let mut level = 0usize;
        'grow: while nodes < budget && level < max_depth && !frontier.is_empty() {
            level += 1;
            let width = if level <= depth { arity } else { 1 };
            let mut next: Vec<Option<usize>> = Vec::new();
            for &p in &frontier {
                for j in 0..width {
                    if nodes >= budget || nodes >= NO_PARENT as usize {
                        break 'grow;
                    }
                    // Row cost: the node plus its own phantom leaf row,
                    // minus the phantom its parent stops needing when it
                    // gains its first child.
                    let first_child_of_node = j == 0 && p.is_some();
                    let delta = if first_child_of_node { 1 } else { 2 };
                    if nodes + leaves + delta > max_rows {
                        break 'grow;
                    }
                    parent.push(match p {
                        None => NO_PARENT,
                        Some(i) => i as u8,
                    });
                    next.push(Some(nodes));
                    nodes += 1;
                    if !first_child_of_node {
                        leaves += 1;
                    }
                }
            }
            if next.is_empty() {
                break;
            }
            frontier = next;
        }
        DraftTree::from_parents(parent).expect("shaped tree is topologically valid")
    }

    pub fn len(&self) -> usize {
        self.parent.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }

    /// The wire form (parent-index array).
    pub fn parents(&self) -> &[u8] {
        &self.parent
    }

    pub fn parent_of(&self, node: usize) -> Option<usize> {
        match self.parent[node] {
            NO_PARENT => None,
            p => Some(p as usize),
        }
    }

    /// Is this the degenerate arity-1 (chain) topology?
    pub fn is_chain(&self) -> bool {
        self.parent
            .iter()
            .enumerate()
            .all(|(i, &p)| if i == 0 { p == NO_PARENT } else { p as usize == i - 1 })
    }

    pub fn root_children(&self) -> &[usize] {
        &self.children[0]
    }

    pub fn children(&self, node: usize) -> &[usize] {
        &self.children[node + 1]
    }

    /// 1-based depth of a node (root children are depth 1).
    pub fn depth(&self, node: usize) -> usize {
        self.depth[node]
    }

    pub fn max_depth(&self) -> usize {
        self.max_depth
    }

    pub fn num_leaves(&self) -> usize {
        self.num_leaves
    }

    /// Engine rows this tree needs: real nodes plus one phantom bonus row
    /// per leaf (an empty tree still needs the row-0 phantom).
    pub fn rows_needed(&self) -> usize {
        if self.parent.is_empty() {
            1
        } else {
            self.parent.len() + self.num_leaves
        }
    }

    /// Engine row of the phantom bonus row after `leaf` (panics on
    /// internal nodes — only leaves terminate an accepted path).
    pub fn bonus_row(&self, leaf: usize) -> usize {
        let r = self.bonus_row[leaf];
        assert!(r != u32::MAX, "node {leaf} is not a leaf");
        r as usize
    }

    /// Node ids from the root down to `node`, inclusive.
    pub fn path_to(&self, node: usize) -> Vec<usize> {
        let mut path = vec![node];
        let mut cur = node;
        while let Some(p) = self.parent_of(cur) {
            path.push(p);
            cur = p;
        }
        path.reverse();
        path
    }

    /// Expected goodput (accepted depth + 1) of verifying this tree under
    /// per-try acceptance probability `alpha`, with sequential sibling
    /// tries: child `j` of a node is reached only after siblings `0..j`
    /// all rejected, so `P(on path) = P(parent) · (1 − α)^j · α`. For a
    /// chain this is exactly `spec::expected_goodput(α, S)`.
    pub fn expected_goodput(&self, alpha: f64) -> f64 {
        let a = alpha.clamp(0.0, 1.0);
        let n = self.len();
        let mut prob = vec![0.0f64; n];
        fn assign(kids: &[usize], parent_prob: f64, a: f64, prob: &mut [f64]) {
            let mut miss = 1.0;
            for &c in kids {
                prob[c] = parent_prob * miss * a;
                miss *= 1.0 - a;
            }
        }
        assign(self.root_children(), 1.0, a, &mut prob);
        for i in 0..n {
            let pi = prob[i];
            assign(self.children(i), pi, a, &mut prob);
        }
        1.0 + prob.iter().sum::<f64>()
    }
}

/// The adaptive per-client shape rule, shared by the live draft server
/// (fed its locally observed acceptance rate) and the analytic simulator
/// (fed α̂): low-acceptance clients branch wide — sibling retries raise
/// the per-level advance probability — while high-acceptance clients
/// spend their whole budget on depth.
pub fn adaptive_profile(alpha: f64) -> (usize, usize) {
    if alpha < 0.45 {
        (3, 8)
    } else if alpha < 0.7 {
        (2, 8)
    } else {
        (1, 32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::expected_goodput;

    #[test]
    fn chain_topology() {
        let t = DraftTree::chain(4);
        assert_eq!(t.len(), 4);
        assert!(t.is_chain());
        assert_eq!(t.parents(), &[NO_PARENT, 0, 1, 2]);
        assert_eq!(t.root_children(), &[0]);
        assert_eq!(t.children(1), &[2]);
        assert_eq!(t.children(3), &[] as &[usize]);
        assert_eq!(t.max_depth(), 4);
        assert_eq!(t.num_leaves(), 1);
        assert_eq!(t.rows_needed(), 5);
        assert_eq!(t.bonus_row(3), 4);
        assert_eq!(t.path_to(3), vec![0, 1, 2, 3]);
        let empty = DraftTree::chain(0);
        assert!(empty.is_empty() && empty.is_chain());
        assert_eq!(empty.rows_needed(), 1);
        assert_eq!(empty.max_depth(), 0);
    }

    #[test]
    fn binary_tree_topology() {
        // Root → {0, 1}; 0 → {2, 3}; 1 → {4}.
        let t = DraftTree::from_parents(vec![NO_PARENT, NO_PARENT, 0, 0, 1]).unwrap();
        assert!(!t.is_chain());
        assert_eq!(t.root_children(), &[0, 1]);
        assert_eq!(t.children(0), &[2, 3]);
        assert_eq!(t.depth(0), 1);
        assert_eq!(t.depth(4), 2);
        assert_eq!(t.max_depth(), 2);
        // Leaves 2, 3, 4 → phantom rows 5, 6, 7.
        assert_eq!(t.num_leaves(), 3);
        assert_eq!(t.rows_needed(), 8);
        assert_eq!(t.bonus_row(2), 5);
        assert_eq!(t.bonus_row(4), 7);
        assert_eq!(t.path_to(3), vec![0, 3]);
        assert_eq!(t.parent_of(4), Some(1));
        assert_eq!(t.parent_of(0), None);
    }

    #[test]
    fn from_parents_rejects_non_topological_order() {
        assert!(DraftTree::from_parents(vec![0]).is_err()); // self-parent
        assert!(DraftTree::from_parents(vec![NO_PARENT, 2, 0]).is_err()); // forward ref
        assert!(DraftTree::from_parents(vec![NO_PARENT, 1]).is_err()); // self
    }

    #[test]
    fn shaped_arity1_is_chain() {
        let t = DraftTree::shaped(1, 8, 5, 32, 64);
        assert!(t.is_chain());
        assert_eq!(t.len(), 5);
        assert_eq!(DraftTree::shaped(1, 8, 0, 32, 64).len(), 0);
    }

    #[test]
    fn shaped_spends_budget_breadth_first() {
        // arity 2, depth 2, budget 6 → levels 2 + 4.
        let t = DraftTree::shaped(2, 2, 6, 32, 64);
        assert_eq!(t.len(), 6);
        assert_eq!(t.root_children().len(), 2);
        assert_eq!(t.children(0).len(), 2);
        assert_eq!(t.children(1).len(), 2);
        assert_eq!(t.max_depth(), 2);
        // Budget beyond the full profile extends chain tails below the
        // frontier (width drops to 1 past the profile depth).
        let t = DraftTree::shaped(2, 1, 6, 32, 64);
        assert_eq!(t.len(), 6);
        assert_eq!(t.max_depth(), 3, "{:?}", t.parents());
        assert_eq!(t.children(0).len(), 1);
        assert_eq!(t.children(1).len(), 1);
        // Partial level: budget 3 on arity-2 depth-2 → 2 + 1 nodes.
        let t = DraftTree::shaped(2, 2, 3, 32, 64);
        assert_eq!(t.len(), 3);
        assert_eq!(t.children(0).len(), 1);
    }

    #[test]
    fn shaped_respects_row_and_depth_caps() {
        // Row cap: nodes + leaves ≤ max_rows.
        for max_rows in 2..=16usize {
            let t = DraftTree::shaped(2, 4, 30, max_rows, 64);
            assert!(t.rows_needed() <= max_rows, "rows {} > {max_rows}", t.rows_needed());
            assert!(t.len() >= 1);
        }
        // Depth cap.
        let t = DraftTree::shaped(1, 32, 30, 64, 3);
        assert_eq!(t.len(), 3);
        assert_eq!(t.max_depth(), 3);
        // Degenerate caps yield the empty tree.
        assert!(DraftTree::shaped(2, 4, 8, 1, 64).is_empty());
        assert!(DraftTree::shaped(2, 4, 8, 32, 0).is_empty());
    }

    #[test]
    fn expected_goodput_matches_chain_closed_form() {
        for &alpha in &[0.0, 0.3, 0.7, 0.95] {
            for s in 0..8usize {
                let t = DraftTree::chain(s);
                let want = expected_goodput(alpha, s);
                assert!(
                    (t.expected_goodput(alpha) - want).abs() < 1e-9,
                    "alpha={alpha} s={s}"
                );
            }
        }
    }

    #[test]
    fn branching_beats_chain_at_low_alpha() {
        // Same 6-node budget: a binary tree outperforms the chain when the
        // acceptance rate is modest (the tentpole's goodput lever) but not
        // when drafts are almost always accepted.
        let chain = DraftTree::chain(6);
        let tree = DraftTree::shaped(2, 3, 6, 32, 64);
        assert_eq!(tree.len(), 6);
        assert!(tree.expected_goodput(0.5) > chain.expected_goodput(0.5));
        assert!(tree.expected_goodput(0.95) < chain.expected_goodput(0.95));
    }

    #[test]
    fn adaptive_profile_widens_at_low_alpha() {
        assert_eq!(adaptive_profile(0.2).0, 3);
        assert_eq!(adaptive_profile(0.6).0, 2);
        assert_eq!(adaptive_profile(0.9).0, 1);
    }
}
