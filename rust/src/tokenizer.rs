//! Byte-level tokenizer (V = 256), mirroring the python training side.
//!
//! The model zoo is trained on ASCII bytes; token id == byte value. Decoding
//! is lossy-printable so logs stay readable even if the model emits
//! non-printable bytes.

pub const VOCAB: usize = 256;

/// Encode text to token ids (non-ASCII chars become '?').
pub fn encode(text: &str) -> Vec<u8> {
    text.chars().map(|c| if c.is_ascii() { c as u8 } else { b'?' }).collect()
}

/// Decode token ids to printable text ('.' for non-printables).
pub fn decode(tokens: &[u8]) -> String {
    tokens
        .iter()
        .map(|&t| {
            let c = t as char;
            if c.is_ascii_graphic() || c == ' ' || c == '\n' {
                c
            } else {
                '.'
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let s = "hello goodspeed 123!";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn non_ascii_replaced() {
        assert_eq!(encode("aé"), vec![b'a', b'?']);
    }

    #[test]
    fn non_printable_bytes_dotted() {
        assert_eq!(decode(&[0u8, 7, b'x']), "..x");
    }

    #[test]
    fn all_bytes_decode_without_panic() {
        let all: Vec<u8> = (0..=255).collect();
        assert_eq!(decode(&all).chars().count(), 256);
    }
}
