//! Thread-local heap-allocation counter for perf assertions.
//!
//! The bench harness (`goodspeed bench`) and the allocation-free-wave
//! tests use this to *prove* the arena'd hot path stays off the heap,
//! instead of eyeballing profiler output. The counting allocator is only
//! registered as the global allocator when the crate is built with
//! `--features alloc_track` (test/bench builds; the default build keeps
//! the plain system allocator). The query API below compiles either way:
//! without the feature the counters simply never move and
//! [`enabled`] reports `false`, so callers can gate their assertions.
//!
//! Counters are per-thread (a `Cell<u64>`, no locks, no heap), so a
//! measurement on the bench thread is not polluted by coordinator or
//! draft-server threads running concurrently.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
    static BYTES: Cell<u64> = const { Cell::new(0) };
}

/// A forwarding allocator that counts this thread's allocation calls.
/// Registered via `#[global_allocator]` in `lib.rs` under the
/// `alloc_track` feature.
pub struct CountingAlloc;

// SAFETY: pure forwarding to `System`; the counters are plain `Cell`s
// with const initializers, so touching them never allocates or unwinds
// (`try_with` covers TLS teardown).
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        let _ = BYTES.try_with(|c| c.set(c.get() + layout.size() as u64));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        let _ = BYTES.try_with(|c| c.set(c.get() + new_size as u64));
        System.realloc(ptr, layout, new_size)
    }
}

/// Whether the counting allocator is actually registered in this build
/// (`--features alloc_track`). When `false`, [`allocations`] is frozen at
/// 0 and [`measure`] always reports 0 — assertions should be skipped.
pub fn enabled() -> bool {
    cfg!(feature = "alloc_track")
}

/// Monotone count of heap allocations performed by the current thread.
pub fn allocations() -> u64 {
    ALLOCS.with(|c| c.get())
}

/// Monotone count of bytes requested from the allocator by this thread
/// (alloc + realloc request sizes; frees are not subtracted).
pub fn bytes_allocated() -> u64 {
    BYTES.with(|c| c.get())
}

/// Run `f` and return its result plus the number of heap allocations the
/// current thread performed inside it (always 0 when [`enabled`] is
/// `false`).
pub fn measure<R>(f: impl FnOnce() -> R) -> (R, u64) {
    let before = allocations();
    let r = f();
    (r, allocations() - before)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_only_with_feature() {
        let (v, allocs) = measure(|| {
            let mut v: Vec<u64> = Vec::with_capacity(64);
            v.push(1);
            v
        });
        assert_eq!(v, vec![1]);
        if enabled() {
            assert!(allocs >= 1, "a fresh Vec must hit the allocator");
        } else {
            assert_eq!(allocs, 0, "counters must stay frozen without the feature");
        }
    }

    #[test]
    fn warm_buffer_reuse_is_allocation_free() {
        // The pattern the wave arenas rely on: clear() + extend within
        // capacity never re-enters the allocator.
        let mut buf: Vec<u8> = Vec::with_capacity(256);
        let (_, allocs) = measure(|| {
            for _ in 0..100 {
                buf.clear();
                buf.extend_from_slice(&[7u8; 200]);
            }
        });
        if enabled() {
            assert_eq!(allocs, 0, "clear+extend within capacity must not allocate");
        }
    }
}
