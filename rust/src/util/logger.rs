//! Minimal `log` backend (level from `GOODSPEED_LOG`, default `info`).

use log::{Level, LevelFilter, Metadata, Record};
use std::io::Write;
use std::sync::Once;
use std::time::Instant;

static INIT: Once = Once::new();
static mut START: Option<Instant> = None;

struct Logger;

impl log::Log for Logger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        // Monotonic seconds since init; good enough for experiment traces.
        let elapsed = unsafe {
            let ptr = &raw const START;
            (*ptr).map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0)
        };
        let tag = match record.level() {
            Level::Error => "E",
            Level::Warn => "W",
            Level::Info => "I",
            Level::Debug => "D",
            Level::Trace => "T",
        };
        let mut err = std::io::stderr().lock();
        let _ = writeln!(err, "[{elapsed:9.3} {tag} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

static LOGGER: Logger = Logger;

/// Install the logger (idempotent).
pub fn init() {
    INIT.call_once(|| {
        unsafe {
            let ptr = &raw mut START;
            *ptr = Some(Instant::now());
        }
        let level = match std::env::var("GOODSPEED_LOG").as_deref() {
            Ok("trace") => LevelFilter::Trace,
            Ok("debug") => LevelFilter::Debug,
            Ok("warn") => LevelFilter::Warn,
            Ok("error") => LevelFilter::Error,
            Ok("off") => LevelFilter::Off,
            _ => LevelFilter::Info,
        };
        let _ = log::set_logger(&LOGGER);
        log::set_max_level(level);
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke");
    }
}
