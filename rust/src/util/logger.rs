//! Minimal `log` backend (level from `GOODSPEED_LOG`, default `info`).

use log::{Level, LevelFilter, Metadata, Record};
use std::io::Write;
use std::sync::{Once, OnceLock};
use std::time::Instant;

static INIT: Once = Once::new();

/// Time zero for the log-line timestamps, set exactly once by [`init`].
/// `OnceLock` replaces the old `static mut` + `unsafe` pattern: same
/// once-only write, no raw-pointer reads on the log path.
static START: OnceLock<Instant> = OnceLock::new();

struct Logger;

impl log::Log for Logger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        // Monotonic seconds since init; good enough for experiment traces.
        let elapsed = START.get().map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
        let tag = match record.level() {
            Level::Error => "E",
            Level::Warn => "W",
            Level::Info => "I",
            Level::Debug => "D",
            Level::Trace => "T",
        };
        let mut err = std::io::stderr().lock();
        let _ = writeln!(err, "[{elapsed:9.3} {tag} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

static LOGGER: Logger = Logger;

/// Parse a `GOODSPEED_LOG` value. `Err` carries the unrecognized value
/// so [`init`] can warn instead of silently defaulting.
fn parse_level(value: &str) -> Result<LevelFilter, ()> {
    match value {
        "trace" => Ok(LevelFilter::Trace),
        "debug" => Ok(LevelFilter::Debug),
        "info" => Ok(LevelFilter::Info),
        "warn" => Ok(LevelFilter::Warn),
        "error" => Ok(LevelFilter::Error),
        "off" => Ok(LevelFilter::Off),
        _ => Err(()),
    }
}

/// Install the logger (idempotent).
pub fn init() {
    INIT.call_once(|| {
        let _ = START.set(Instant::now());
        let level = match std::env::var("GOODSPEED_LOG") {
            Ok(v) => parse_level(&v).unwrap_or_else(|()| {
                eprintln!(
                    "goodspeed: unrecognized GOODSPEED_LOG value '{v}' \
                     (expected trace|debug|info|warn|error|off); defaulting to info"
                );
                LevelFilter::Info
            }),
            Err(_) => LevelFilter::Info,
        };
        let _ = log::set_logger(&LOGGER);
        log::set_max_level(level);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke");
        assert!(START.get().is_some(), "init must set the time zero");
    }

    #[test]
    fn level_parsing_accepts_the_documented_values_only() {
        assert_eq!(parse_level("trace"), Ok(LevelFilter::Trace));
        assert_eq!(parse_level("debug"), Ok(LevelFilter::Debug));
        assert_eq!(parse_level("info"), Ok(LevelFilter::Info));
        assert_eq!(parse_level("warn"), Ok(LevelFilter::Warn));
        assert_eq!(parse_level("error"), Ok(LevelFilter::Error));
        assert_eq!(parse_level("off"), Ok(LevelFilter::Off));
        assert_eq!(parse_level("verbose"), Err(()), "unknown values must be flagged");
        assert_eq!(parse_level("INFO"), Err(()), "matching is exact, like before");
    }
}
