//! Foundation substrates built from scratch (the offline crate set contains
//! only the `xla` closure, so PRNG, stats, logging, timing, and the property
//! test driver are all first-class local implementations).

pub mod alloc_track;
pub mod logger;
pub mod perfjson;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod timer;
pub mod wakeup;

pub use prng::Rng;
pub use stats::{jain_index, p50_p95_p99, percentile, MovingAvg, RunningStat};
pub use timer::Stopwatch;
pub use wakeup::Wakeup;
