//! Minimal JSON value + writer + parser for the perf harness
//! (`BENCH_<n>.json`). The offline crate set has no serde, so this is a
//! small hand-rolled implementation: objects keep insertion order (stable
//! diffs across recordings), numbers are `f64`, and the parser is a
//! recursive-descent total function returning typed errors (never
//! panics on malformed input).

use std::fmt::Write as _;

use anyhow::{anyhow, Result};

/// A JSON value. Objects preserve insertion order.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object member lookup (first match); `None` on non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Walk a `.`-separated member path through nested objects.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for key in path.split('.') {
            cur = cur.get(key)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Append `(key, value)` to an object (panics on non-objects — the
    /// builder is only used on values we construct ourselves).
    pub fn insert(&mut self, key: &str, value: Json) {
        match self {
            Json::Obj(members) => members.push((key.to_string(), value)),
            _ => panic!("insert on non-object"),
        }
    }

    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Pretty-print with 2-space indentation and a trailing newline —
    /// the `BENCH_<n>.json` on-disk format.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    pad(out, indent + 1);
                    v.write(out, indent + 1);
                }
                out.push('\n');
                pad(out, indent);
                out.push(']');
            }
            Json::Obj(members) => {
                if members.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in members.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    pad(out, indent + 1);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                pad(out, indent);
                out.push('}');
            }
        }
    }
}

fn pad(out: &mut String, indent: usize) {
    for _ in 0..indent {
        out.push_str("  ");
    }
}

fn write_num(out: &mut String, x: f64) {
    if !x.is_finite() {
        out.push_str("null"); // JSON has no Inf/NaN
    } else if x == x.trunc() && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document (total: typed error, never a panic).
pub fn parse(text: &str) -> Result<Json> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let value = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(anyhow!("trailing bytes at offset {pos}"));
    }
    Ok(value)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<()> {
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        Err(anyhow!("expected '{}' at offset {}", c as char, *pos))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err(anyhow!("unexpected end of input")),
        Some(b'{') => parse_obj(b, pos),
        Some(b'[') => parse_arr(b, pos),
        Some(b'"') => Ok(Json::Str(parse_string(b, pos)?)),
        Some(b't') => parse_lit(b, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false", Json::Bool(false)),
        Some(b'n') => parse_lit(b, pos, "null", Json::Null),
        Some(_) => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, value: Json) -> Result<Json> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        Err(anyhow!("invalid literal at offset {}", *pos))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos]).map_err(|_| anyhow!("bad number"))?;
    text.parse::<f64>().map(Json::Num).map_err(|_| anyhow!("bad number '{text}' at {start}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => return Err(anyhow!("unterminated string")),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000c}'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                        let hex = std::str::from_utf8(hex).map_err(|_| anyhow!("bad escape"))?;
                        let code =
                            u32::from_str_radix(hex, 16).map_err(|_| anyhow!("bad \\u escape"))?;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => return Err(anyhow!("bad escape at offset {}", *pos)),
                }
                *pos += 1;
            }
            Some(&c) => {
                // Copy the raw UTF-8 bytes of one code point.
                let len = utf8_len(c);
                let chunk =
                    b.get(*pos..*pos + len).ok_or_else(|| anyhow!("truncated utf-8"))?;
                out.push_str(std::str::from_utf8(chunk).map_err(|_| anyhow!("bad utf-8"))?);
                *pos += len;
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json> {
    expect(b, pos, b'[')?;
    let mut items = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => return Err(anyhow!("expected ',' or ']' at offset {}", *pos)),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json> {
    expect(b, pos, b'{')?;
    let mut members = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(members));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        expect(b, pos, b':')?;
        members.push((key, parse_value(b, pos)?));
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(members));
            }
            _ => return Err(anyhow!("expected ',' or '}}' at offset {}", *pos)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_bench_shape() {
        let mut presets = Json::obj();
        let mut sharded = Json::obj();
        sharded.insert("waves_per_sec", Json::Num(123.5));
        sharded.insert("tokens_per_sec", Json::Num(4096.0));
        sharded.insert("slo_tokens_per_sec", Json::Null);
        presets.insert("sharded", sharded);
        let mut doc = Json::obj();
        doc.insert("version", Json::Num(1.0));
        doc.insert("quick", Json::Bool(true));
        doc.insert("presets", presets);
        let text = doc.pretty();
        let back = parse(&text).unwrap();
        assert_eq!(back, doc);
        assert_eq!(back.path("presets.sharded.waves_per_sec").unwrap().as_f64(), Some(123.5));
        assert_eq!(back.path("presets.sharded.tokens_per_sec").unwrap().as_f64(), Some(4096.0));
        assert!(back.path("presets.missing").is_none());
    }

    #[test]
    fn integers_print_without_fraction() {
        let mut s = String::new();
        write_num(&mut s, 4096.0);
        assert_eq!(s, "4096");
        let mut s = String::new();
        write_num(&mut s, 1.25);
        assert_eq!(s, "1.25");
    }

    #[test]
    fn parses_escapes_and_nesting() {
        let j = parse(r#"{"a": [1, -2.5e1, "x\ny\u0041"], "b": {"c": null}}"#).unwrap();
        match j.path("a").unwrap() {
            Json::Arr(items) => {
                assert_eq!(items[0].as_f64(), Some(1.0));
                assert_eq!(items[1].as_f64(), Some(-25.0));
                assert_eq!(items[2].as_str(), Some("x\nyA"));
            }
            _ => panic!("a must be an array"),
        }
        assert_eq!(j.path("b.c"), Some(&Json::Null));
    }

    #[test]
    fn malformed_inputs_yield_errors_not_panics() {
        for bad in [
            "", "{", "[1,", "{\"a\" 1}", "{\"a\": }", "tru", "\"unterminated",
            "{\"a\": 1} trailing", "nul", "[1 2]", "{\"a\": +}", "\"\\u12\"", "\"\\q\"",
        ] {
            assert!(parse(bad).is_err(), "must reject {bad:?}");
        }
    }
}
