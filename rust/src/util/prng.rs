//! xoshiro256++ PRNG with splitmix64 seeding.
//!
//! Deterministic, seedable, and fast — every stochastic component in the
//! system (sampling draft tokens, rejection draws, workload generation,
//! Random-S baseline, simulated jitter) derives from this so whole
//! experiments replay bit-exactly from a scenario seed.

/// xoshiro256++ by Blackman & Vigna (public domain reference constants).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Independent child stream (used to give each draft server / domain
    /// its own stream that is stable regardless of sibling consumption).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n). Lemire's rejection method (unbiased).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample an index from an (unnormalized, non-negative) weight slice.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w.max(0.0) as f64).sum();
        if total <= 0.0 {
            // Degenerate distribution: fall back to uniform.
            return self.below(weights.len() as u64) as usize;
        }
        let mut u = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w.max(0.0) as f64;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(2);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut r = Rng::new(3);
        let mut counts = [0u32; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            let expect = n as f64 / 7.0;
            assert!((c as f64 - expect).abs() < 5.0 * expect.sqrt(), "{counts:?}");
        }
    }

    #[test]
    fn categorical_follows_weights() {
        let mut r = Rng::new(4);
        let w = [1.0f32, 3.0, 6.0];
        let mut counts = [0u32; 3];
        let n = 100_000;
        for _ in 0..n {
            counts[r.categorical(&w)] += 1;
        }
        assert!((counts[0] as f64 / n as f64 - 0.1).abs() < 0.01);
        assert!((counts[1] as f64 / n as f64 - 0.3).abs() < 0.01);
        assert!((counts[2] as f64 / n as f64 - 0.6).abs() < 0.01);
    }

    #[test]
    fn categorical_degenerate_weights() {
        let mut r = Rng::new(5);
        let idx = r.categorical(&[0.0, 0.0, 0.0]);
        assert!(idx < 3);
        // single spike
        for _ in 0..100 {
            assert_eq!(r.categorical(&[0.0, 1.0, 0.0]), 1);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(7);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_differ() {
        let mut root = Rng::new(8);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
