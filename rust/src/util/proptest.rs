//! Mini property-testing driver (proptest is not in the offline crate set).
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` independently
//! seeded PRNGs; on failure it reports the failing seed so the case replays
//! deterministically with `GOODSPEED_PROP_SEED=<seed> cargo test <name>`.

use super::prng::Rng;

/// Number of cases per property (override with GOODSPEED_PROP_CASES).
pub fn default_cases() -> u64 {
    std::env::var("GOODSPEED_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` across seeded cases; panic with the failing seed on error.
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: u64, mut prop: F) {
    if let Ok(seed) = std::env::var("GOODSPEED_PROP_SEED") {
        let seed: u64 = seed.parse().expect("GOODSPEED_PROP_SEED must be u64");
        let mut rng = Rng::new(seed);
        prop(&mut rng);
        return;
    }
    for case in 0..cases {
        // Mix the property name into the seed stream so distinct properties
        // explore distinct inputs.
        let tag = name.bytes().fold(0u64, |h, b| h.wrapping_mul(131).wrapping_add(b as u64));
        let seed = tag.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        }));
        if let Err(err) = result {
            eprintln!("property '{name}' failed at case {case}; replay with GOODSPEED_PROP_SEED={seed}");
            std::panic::resume_unwind(err);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut count = 0;
        check("counter", 16, |_| count += 1);
        assert_eq!(count, 16);
    }

    #[test]
    #[should_panic]
    fn propagates_failure() {
        check("fails", 8, |rng| {
            assert!(rng.f64() < 2.0); // always true…
            assert!(false); // …then force a failure
        });
    }
}
