//! Streaming statistics used throughout metrics and experiments.

/// Welford online mean/variance.
#[derive(Clone, Debug, Default)]
pub struct RunningStat {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl RunningStat {
    pub fn new() -> Self {
        RunningStat { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }

    pub fn sum(&self) -> f64 {
        self.mean * self.n as f64
    }
}

/// Fixed-window moving average + variance (Fig 2 uses MA(10) with ±1 std
/// confidence bands around both curves).
#[derive(Clone, Debug)]
pub struct MovingAvg {
    window: usize,
    buf: Vec<f64>,
    head: usize,
    filled: bool,
}

impl MovingAvg {
    pub fn new(window: usize) -> Self {
        assert!(window > 0);
        MovingAvg { window, buf: Vec::with_capacity(window), head: 0, filled: false }
    }

    pub fn push(&mut self, x: f64) {
        if self.buf.len() < self.window {
            self.buf.push(x);
        } else {
            self.buf[self.head] = x;
            self.head = (self.head + 1) % self.window;
            self.filled = true;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.buf.is_empty() {
            return 0.0;
        }
        self.buf.iter().sum::<f64>() / self.buf.len() as f64
    }

    pub fn variance(&self) -> f64 {
        if self.buf.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        self.buf.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (self.buf.len() - 1) as f64
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Jain fairness index: (Σx)² / (n·Σx²) ∈ [1/n, 1]; 1 = perfectly fair.
pub fn jain_index(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let s: f64 = xs.iter().sum();
    let s2: f64 = xs.iter().map(|x| x * x).sum();
    if s2 <= 0.0 {
        return 1.0;
    }
    s * s / (xs.len() as f64 * s2)
}

/// Exact quantile by sorting a copy (fine for per-experiment reporting).
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_stat_matches_closed_form() {
        let mut s = RunningStat::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn moving_avg_window_semantics() {
        let mut ma = MovingAvg::new(3);
        ma.push(1.0);
        assert!((ma.mean() - 1.0).abs() < 1e-12);
        ma.push(2.0);
        ma.push(3.0);
        assert!((ma.mean() - 2.0).abs() < 1e-12);
        ma.push(10.0); // evicts 1.0
        assert!((ma.mean() - 5.0).abs() < 1e-12);
        ma.push(10.0);
        ma.push(10.0);
        assert!((ma.mean() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn moving_avg_std_constant_is_zero() {
        let mut ma = MovingAvg::new(5);
        for _ in 0..10 {
            ma.push(4.2);
        }
        assert!(ma.std() < 1e-12);
    }

    #[test]
    fn jain_bounds() {
        assert!((jain_index(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
        let n = 4;
        let skew = jain_index(&[1.0, 0.0, 0.0, 0.0]);
        assert!((skew - 1.0 / n as f64).abs() < 1e-12);
        let mid = jain_index(&[3.0, 1.0]);
        assert!(mid > 0.5 && mid < 1.0);
    }

    #[test]
    fn jain_edge_cases() {
        // This is the *single* Jain implementation — experiments, metrics,
        // the simulator, and the benches all import it from here.
        // Empty slice: vacuously fair.
        assert!((jain_index(&[]) - 1.0).abs() < 1e-12);
        // Single client: always perfectly fair, whatever the value.
        assert!((jain_index(&[7.3]) - 1.0).abs() < 1e-12);
        assert!((jain_index(&[0.0]) - 1.0).abs() < 1e-12);
        // All-equal vectors are fair at any scale and length.
        for n in [2usize, 5, 64] {
            let xs = vec![0.25; n];
            assert!((jain_index(&xs) - 1.0).abs() < 1e-12, "n = {n}");
        }
        // All-zero (no goodput anywhere) degenerates to fair, not NaN.
        assert!((jain_index(&[0.0, 0.0, 0.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn quantile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((quantile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((quantile(&xs, 1.0) - 4.0).abs() < 1e-12);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }
}
