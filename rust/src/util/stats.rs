//! Streaming statistics used throughout metrics and experiments.

/// Welford online mean/variance.
#[derive(Clone, Debug, Default)]
pub struct RunningStat {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl RunningStat {
    pub fn new() -> Self {
        RunningStat { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }

    pub fn sum(&self) -> f64 {
        self.mean * self.n as f64
    }
}

/// Fixed-window moving average + variance (Fig 2 uses MA(10) with ±1 std
/// confidence bands around both curves).
#[derive(Clone, Debug)]
pub struct MovingAvg {
    window: usize,
    buf: Vec<f64>,
    head: usize,
    filled: bool,
}

impl MovingAvg {
    pub fn new(window: usize) -> Self {
        assert!(window > 0);
        MovingAvg { window, buf: Vec::with_capacity(window), head: 0, filled: false }
    }

    pub fn push(&mut self, x: f64) {
        if self.buf.len() < self.window {
            self.buf.push(x);
        } else {
            self.buf[self.head] = x;
            self.head = (self.head + 1) % self.window;
            self.filled = true;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.buf.is_empty() {
            return 0.0;
        }
        self.buf.iter().sum::<f64>() / self.buf.len() as f64
    }

    pub fn variance(&self) -> f64 {
        if self.buf.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        self.buf.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (self.buf.len() - 1) as f64
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Jain fairness index: (Σx)² / (n·Σx²) ∈ [1/n, 1]; 1 = perfectly fair.
pub fn jain_index(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let s: f64 = xs.iter().sum();
    let s2: f64 = xs.iter().map(|x| x * x).sum();
    if s2 <= 0.0 {
        return 1.0;
    }
    s * s / (xs.len() as f64 * s2)
}

/// Exact quantile by sorting a copy (fine for per-experiment reporting).
/// `q ∈ [0, 1]`; see [`percentile`] for the `[0, 100]`-scaled form every
/// report column uses.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile_sorted(&v, q)
}

/// Linear-interpolation quantile over an already-sorted slice — the one
/// interpolation rule (the "linear"/type-7 estimator: position
/// `q·(n−1)`, interpolate between the straddling order statistics) every
/// percentile consumer shares.
fn quantile_sorted(v: &[f64], q: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

/// Linear-interpolation percentile, `p ∈ [0, 100]` (p50/p95/p99 report
/// columns). Empty input yields 0 — report rows stay well-defined before
/// the first request completes. Single-element and all-duplicate inputs
/// return that value at every p.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    quantile(xs, p / 100.0)
}

/// The standard report triple (p50, p95, p99) of a sample.
pub fn p50_p95_p99(xs: &[f64]) -> (f64, f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0, 0.0);
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (quantile_sorted(&v, 0.50), quantile_sorted(&v, 0.95), quantile_sorted(&v, 0.99))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_stat_matches_closed_form() {
        let mut s = RunningStat::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn moving_avg_window_semantics() {
        let mut ma = MovingAvg::new(3);
        ma.push(1.0);
        assert!((ma.mean() - 1.0).abs() < 1e-12);
        ma.push(2.0);
        ma.push(3.0);
        assert!((ma.mean() - 2.0).abs() < 1e-12);
        ma.push(10.0); // evicts 1.0
        assert!((ma.mean() - 5.0).abs() < 1e-12);
        ma.push(10.0);
        ma.push(10.0);
        assert!((ma.mean() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn moving_avg_std_constant_is_zero() {
        let mut ma = MovingAvg::new(5);
        for _ in 0..10 {
            ma.push(4.2);
        }
        assert!(ma.std() < 1e-12);
    }

    #[test]
    fn jain_bounds() {
        assert!((jain_index(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
        let n = 4;
        let skew = jain_index(&[1.0, 0.0, 0.0, 0.0]);
        assert!((skew - 1.0 / n as f64).abs() < 1e-12);
        let mid = jain_index(&[3.0, 1.0]);
        assert!(mid > 0.5 && mid < 1.0);
    }

    #[test]
    fn jain_edge_cases() {
        // This is the *single* Jain implementation — experiments, metrics,
        // the simulator, and the benches all import it from here.
        // Empty slice: vacuously fair.
        assert!((jain_index(&[]) - 1.0).abs() < 1e-12);
        // Single client: always perfectly fair, whatever the value.
        assert!((jain_index(&[7.3]) - 1.0).abs() < 1e-12);
        assert!((jain_index(&[0.0]) - 1.0).abs() < 1e-12);
        // All-equal vectors are fair at any scale and length.
        for n in [2usize, 5, 64] {
            let xs = vec![0.25; n];
            assert!((jain_index(&xs) - 1.0).abs() < 1e-12, "n = {n}");
        }
        // All-zero (no goodput anywhere) degenerates to fair, not NaN.
        assert!((jain_index(&[0.0, 0.0, 0.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn quantile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((quantile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((quantile(&xs, 1.0) - 4.0).abs() < 1e-12);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_edge_cases() {
        // Empty: well-defined 0 (reports render before any request ends).
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(p50_p95_p99(&[]), (0.0, 0.0, 0.0));
        // Single element: that value at every p.
        for p in [0.0, 37.0, 50.0, 99.0, 100.0] {
            assert!((percentile(&[4.2], p) - 4.2).abs() < 1e-12, "p = {p}");
        }
        // Duplicates: constant samples are constant at every p.
        let dup = [7.0; 9];
        for p in [0.0, 25.0, 50.0, 95.0, 100.0] {
            assert!((percentile(&dup, p) - 7.0).abs() < 1e-12, "p = {p}");
        }
        // Mixed duplicates interpolate between the order statistics.
        let xs = [1.0, 1.0, 1.0, 2.0];
        assert!((percentile(&xs, 50.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 2.0).abs() < 1e-12);
        // Out-of-range p clamps.
        assert!((percentile(&xs, -10.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 240.0) - 2.0).abs() < 1e-12);
        // Unsorted input is handled (sorting is internal).
        assert!((percentile(&[3.0, 1.0, 2.0], 50.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_triple_matches_scalar_calls() {
        let xs = [5.0, 1.0, 9.0, 3.0, 7.0];
        let (p50, p95, p99) = p50_p95_p99(&xs);
        assert!((p50 - percentile(&xs, 50.0)).abs() < 1e-12);
        assert!((p95 - percentile(&xs, 95.0)).abs() < 1e-12);
        assert!((p99 - percentile(&xs, 99.0)).abs() < 1e-12);
    }

    /// Property: against a sorted-scan reference implementation — the
    /// interpolated value lies between the straddling order statistics,
    /// exact at integer positions, monotone in p, and within the sample
    /// range everywhere.
    #[test]
    fn prop_percentile_matches_sorted_scan_reference() {
        crate::util::proptest::check(
            "percentile_reference",
            crate::util::proptest::default_cases(),
            |rng| {
                let n = 1 + rng.below(40) as usize;
                // Draws from a small integer lattice force duplicates.
                let xs: Vec<f64> = (0..n).map(|_| rng.below(8) as f64).collect();
                let mut sorted = xs.clone();
                sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let mut prev = f64::NEG_INFINITY;
                for step in 0..=20 {
                    let p = step as f64 * 5.0;
                    let got = percentile(&xs, p);
                    // Reference: scan the sorted copy at position q·(n−1).
                    let pos = (p / 100.0) * (n - 1) as f64;
                    let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
                    let want = sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64);
                    assert!((got - want).abs() < 1e-9, "p={p}: {got} vs {want}");
                    assert!(got >= sorted[0] - 1e-9 && got <= sorted[n - 1] + 1e-9);
                    assert!(got >= prev - 1e-9, "percentile must be monotone in p");
                    prev = got;
                }
            },
        );
    }
}
