//! Wall-clock timing helpers for the Fig 3 time decomposition.

use std::time::{Duration, Instant};

/// Stopwatch with named laps; used by the coordinator to attribute each
/// round's wall time to receive / verify / send (paper §IV-B2).
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    last: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Stopwatch { start: now, last: now }
    }

    /// Time since the previous lap (or construction), resetting the lap.
    pub fn lap(&mut self) -> Duration {
        let now = Instant::now();
        let d = now - self.last;
        self.last = now;
        d
    }

    /// Total time since construction.
    pub fn total(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn reset(&mut self) {
        let now = Instant::now();
        self.start = now;
        self.last = now;
    }
}

/// Run `f` and return (result, elapsed).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laps_accumulate_to_total() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(2));
        let a = sw.lap();
        std::thread::sleep(Duration::from_millis(2));
        let b = sw.lap();
        assert!(a >= Duration::from_millis(1));
        assert!(b >= Duration::from_millis(1));
        assert!(sw.total() >= a + b);
    }

    #[test]
    fn timed_measures() {
        let (v, d) = timed(|| {
            std::thread::sleep(Duration::from_millis(2));
            7
        });
        assert_eq!(v, 7);
        assert!(d >= Duration::from_millis(1));
    }
}
