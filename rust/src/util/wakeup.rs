//! Condvar-backed wakeup for idle coordinator loops.
//!
//! The pool driver and the shard loops used to poll with a fixed 2ms
//! sleep tick: an arrival landing just after a shard went idle waited out
//! the rest of the tick before anyone looked. [`Wakeup`] replaces that
//! with a sequence-stamped condvar so a notified waiter unparks in
//! microseconds, while keeping the timeout as a liveness backstop (a
//! waiter still wakes on its own to re-check stop flags and publish
//! freshness).
//!
//! The sequence counter makes the primitive lost-wakeup-free without any
//! allocation: a waiter snapshots [`Wakeup::seq`] *before* re-checking
//! the state it sleeps on, then parks in [`Wakeup::wait_timeout`] with
//! that snapshot — a notification racing the state check bumps the
//! counter, so the wait returns immediately instead of sleeping through
//! the event.

use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// A lost-wakeup-free notification counter (see module docs).
#[derive(Debug, Default)]
pub struct Wakeup {
    seq: Mutex<u64>,
    cv: Condvar,
}

impl Wakeup {
    pub fn new() -> Wakeup {
        Wakeup::default()
    }

    /// Wake every current and future waiter whose snapshot predates this
    /// call.
    pub fn notify(&self) {
        let mut seq = self.seq.lock().expect("wakeup lock");
        *seq = seq.wrapping_add(1);
        drop(seq);
        self.cv.notify_all();
    }

    /// Snapshot the notification counter. Take this *before* checking the
    /// condition you are about to sleep on.
    pub fn seq(&self) -> u64 {
        *self.seq.lock().expect("wakeup lock")
    }

    /// Park until the counter moves past `last_seen` or `dur` elapses,
    /// whichever comes first. Returns the counter at wake (pass it back
    /// as the next `last_seen` to wait for the *next* notification).
    pub fn wait_timeout(&self, last_seen: u64, dur: Duration) -> u64 {
        let guard = self.seq.lock().expect("wakeup lock");
        let (guard, _timed_out) = self
            .cv
            .wait_timeout_while(guard, dur, |seq| *seq == last_seen)
            .expect("wakeup lock");
        *guard
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Instant;

    #[test]
    fn notify_advances_seq_and_unblocks_stale_snapshot() {
        let w = Wakeup::new();
        let s0 = w.seq();
        w.notify();
        assert_eq!(w.seq(), s0 + 1);
        // A snapshot taken before the notify returns without sleeping.
        let t0 = Instant::now();
        let s1 = w.wait_timeout(s0, Duration::from_secs(5));
        assert!(t0.elapsed() < Duration::from_secs(1));
        assert_eq!(s1, s0 + 1);
    }

    #[test]
    fn wait_times_out_without_notification() {
        let w = Wakeup::new();
        let seen = w.seq();
        let t0 = Instant::now();
        let after = w.wait_timeout(seen, Duration::from_millis(5));
        assert!(t0.elapsed() >= Duration::from_millis(5));
        assert_eq!(after, seen);
    }

    /// Concurrent notifiers: every `notify` bumps the counter exactly
    /// once (no lost updates under contention), and a waiter chasing the
    /// counter with stale snapshots observes it monotonically all the
    /// way to the final count — no notification is slept through.
    #[test]
    fn concurrent_notifiers_never_lose_a_count() {
        const THREADS: u64 = 8;
        const NOTIFIES: u64 = 200;
        let w = Arc::new(Wakeup::new());
        let s0 = w.seq();
        let target = s0 + THREADS * NOTIFIES;
        let chaser = {
            let w = w.clone();
            std::thread::spawn(move || {
                let mut seen = s0;
                let mut observed = Vec::new();
                while seen < target {
                    let next = w.wait_timeout(seen, Duration::from_millis(50));
                    assert!(next >= seen, "counter went backwards: {next} < {seen}");
                    observed.push(next);
                    seen = next;
                }
                observed
            })
        };
        let notifiers: Vec<_> = (0..THREADS)
            .map(|_| {
                let w = w.clone();
                std::thread::spawn(move || {
                    for _ in 0..NOTIFIES {
                        w.notify();
                    }
                })
            })
            .collect();
        for h in notifiers {
            h.join().expect("notifier");
        }
        let observed = chaser.join().expect("chaser");
        assert_eq!(w.seq(), target);
        assert!(observed.windows(2).all(|p| p[0] <= p[1]), "non-monotonic observations");
        assert_eq!(*observed.last().expect("progress"), target);
    }

    /// Spurious-wakeup discipline: the contract is "returns the current
    /// counter", not "returns because of a notification" — callers
    /// re-check their condition on every return. A stale snapshot must
    /// therefore return immediately however many times it is retried,
    /// while a fresh snapshot is *not* woken by past notifications.
    #[test]
    fn stale_snapshot_returns_immediately_on_every_retry() {
        let w = Wakeup::new();
        let s0 = w.seq();
        w.notify();
        w.notify();
        for _ in 0..100 {
            let t0 = Instant::now();
            let cur = w.wait_timeout(s0, Duration::from_secs(5));
            assert_eq!(cur, s0 + 2);
            assert!(t0.elapsed() < Duration::from_secs(1));
        }
        let seen = w.seq();
        let t0 = Instant::now();
        assert_eq!(w.wait_timeout(seen, Duration::from_millis(5)), seen);
        assert!(t0.elapsed() >= Duration::from_millis(5));
    }

    /// The point of the primitive: an idle waiter observes a notification
    /// in well under one former 2ms sleep tick. Measured notify→wake on a
    /// parked thread, min over repeated trials (min, not mean, so a noisy
    /// CI runner preempting one trial cannot fail the assertion — the
    /// claim is about the primitive's latency, not the scheduler's).
    #[test]
    fn parked_waiter_wakes_well_under_former_tick() {
        const TRIALS: usize = 20;
        let mut best = Duration::MAX;
        for _ in 0..TRIALS {
            let w = Arc::new(Wakeup::new());
            let w2 = w.clone();
            let seen = w.seq();
            let waiter = std::thread::spawn(move || {
                w2.wait_timeout(seen, Duration::from_secs(5));
                Instant::now()
            });
            // Give the waiter time to park before notifying.
            std::thread::sleep(Duration::from_millis(1));
            let t0 = Instant::now();
            w.notify();
            let woke = waiter.join().expect("waiter");
            best = best.min(woke.saturating_duration_since(t0));
        }
        assert!(
            best < Duration::from_micros(500),
            "best notify→wake latency {best:?} not well under the former 2ms tick"
        );
    }
}
