//! The eight dataset analogs (paper §IV-A2), mirrored from
//! `python/compile/corpus.py` so serving-time prompts are in-distribution
//! for the build-time-trained models.
//!
//! Domain *predictability* varies deliberately: template-heavy domains
//! (alpaca, spider) are easy for a weak draft model to imitate → high
//! acceptance rate α; the long-tail domain (hle) is nearly incompressible →
//! low α. That spread is what makes the fairness problem non-trivial.

use anyhow::{anyhow, Result};

use crate::util::Rng;

pub const VERBS: [&str; 8] =
    ["describe", "explain", "list", "sort", "count", "compare", "find", "name"];
pub const NOUNS: [&str; 8] =
    ["river", "planet", "engine", "garden", "market", "signal", "bridge", "forest"];
pub const ROLES: [&str; 8] =
    ["teacher", "pilot", "doctor", "coach", "writer", "farmer", "guide", "judge"];
pub const PLACES: [&str; 8] =
    ["paris", "tokyo", "cairo", "lima", "oslo", "delhi", "rome", "quito"];
pub const DAYS: [&str; 7] =
    ["monday", "tuesday", "wednesday", "thursday", "friday", "saturday", "sunday"];
pub const NAMES: [&str; 8] = ["tom", "ana", "raj", "mia", "leo", "zoe", "sam", "eva"];
pub const FIELDS: [&str; 8] =
    ["age", "price", "score", "size", "rank", "count", "level", "speed"];
pub const RARE: [&str; 16] = [
    "zyx", "qov", "vex", "juf", "wib", "kah", "pyx", "gud", "nix", "fiz", "yam", "ojo", "ulu",
    "ebb", "awn", "irk",
];

/// Dataset analog names in paper order.
pub const DOMAINS: [&str; 8] =
    ["alpaca", "prompts", "cnn", "orca", "arena", "gsm8k", "spider", "hle"];

/// Generate one prompt for a domain (the serving-side half of the
/// templates; completions are what the models were trained to produce).
///
/// Unknown domains are a configuration error, reported as `Err` (and
/// caught earlier by `Scenario::validate`) rather than a panic.
pub fn prompt(domain: &str, rng: &mut Rng) -> Result<String> {
    let p = match domain {
        "alpaca" => {
            let v = rng.choose(&VERBS);
            let n = rng.choose(&NOUNS);
            format!("### Instruction: {v} the {n}. ### Response:")
        }
        "prompts" => {
            let role = rng.choose(&ROLES);
            format!("act as a {role}.")
        }
        "cnn" => {
            let n = rng.choose(&NOUNS);
            let p = rng.choose(&PLACES);
            let d = rng.choose(&DAYS);
            format!("breaking news: the {n} in {p} opened on {d}. summary:")
        }
        "orca" => {
            let a = rng.choose(&NOUNS);
            let b = rng.choose(&NOUNS);
            format!("question: is a {a} larger than a {b}? think step by step.")
        }
        "arena" => "hello how are you today?".to_string(),
        "gsm8k" => {
            let name = rng.choose(&NAMES);
            let a = rng.range_u(1, 9);
            let b = rng.range_u(1, 9);
            format!("q: {name} has {a} apples and buys {b} more. how many apples?")
        }
        "spider" => {
            let n = rng.choose(&NOUNS);
            let f = rng.choose(&FIELDS);
            let num = rng.range_u(10, 99);
            format!("q: list all {n}s with {f} above {num} | sql:")
        }
        "hle" => {
            let words: Vec<&str> = (0..3).map(|_| *rng.choose(&RARE)).collect();
            format!("decode: {}", words.join(" "))
        }
        other => {
            return Err(anyhow!(
                "unknown domain '{other}' (known: {})",
                DOMAINS.join(", ")
            ))
        }
    };
    Ok(p)
}

/// Is this a known domain?
pub fn is_domain(name: &str) -> bool {
    DOMAINS.contains(&name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eight_domains_like_the_paper() {
        assert_eq!(DOMAINS.len(), 8);
    }

    #[test]
    fn all_domains_generate() {
        let mut rng = Rng::new(0);
        for d in DOMAINS {
            for _ in 0..20 {
                let p = prompt(d, &mut rng).unwrap();
                assert!(p.is_ascii());
                assert!((5..=120).contains(&p.len()), "{d}: '{p}'");
            }
        }
    }

    #[test]
    fn prompts_deterministic_per_seed() {
        let mut a = Rng::new(3);
        let mut b = Rng::new(3);
        for d in DOMAINS {
            assert_eq!(prompt(d, &mut a).unwrap(), prompt(d, &mut b).unwrap());
        }
    }

    #[test]
    fn unknown_domain_is_an_error_not_a_panic() {
        let err = prompt("nope", &mut Rng::new(0)).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown domain 'nope'"), "{msg}");
        assert!(msg.contains("alpaca"), "should list known domains: {msg}");
    }
}
