//! Workload generation: the eight dataset analogs and non-stationary
//! per-client prompt streams.

pub mod domains;
pub mod stream;

pub use domains::DOMAINS;
pub use stream::{DomainStream, Request};
