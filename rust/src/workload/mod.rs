//! Workload generation: the eight dataset analogs and non-stationary
//! per-client prompt streams.
//!
//! Streams are *closed-loop*: [`DomainStream::next_request`] always has
//! the next prompt ready, which is what the paper's goodput experiments
//! measure. The request-level serving layer (`serve/`) turns this into
//! an *open-loop* workload by layering a trace of discrete arrivals on
//! top — the stream still supplies the token content, while
//! [`serve::RequestTrace`](crate::serve::RequestTrace) decides when a
//! client has work at all (idle clients are granted no speculation
//! budget) and [`serve::RequestTracker`](crate::serve::RequestTracker)
//! accounts each request's TTFT/TPOT/E2E and SLO outcome.

pub mod domains;
pub mod stream;

pub use domains::DOMAINS;
pub use stream::{DomainStream, Request};
