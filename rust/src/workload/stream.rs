//! Non-stationary per-client prompt streams.
//!
//! Each draft server serves one end-user whose requests follow a Markov
//! domain process: with probability `stickiness` the next request stays in
//! the client's primary domain, otherwise it jumps to a uniformly random
//! other domain. Domain shifts change the *true* acceptance rate mid-run —
//! the non-stationarity that GoodSpeed's smoothed estimators must track
//! (paper §III-B "dynamic evolution of client prompts").

use super::domains::{self, DOMAINS};
use crate::util::Rng;

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub prompt: String,
    pub domain: &'static str,
    pub max_new_tokens: usize,
    /// Sequence number within the client's stream.
    pub seq: u64,
}

/// Markov-switching prompt stream for one client.
#[derive(Clone, Debug)]
pub struct DomainStream {
    primary: &'static str,
    current: &'static str,
    stickiness: f64,
    max_new_tokens: usize,
    rng: Rng,
    seq: u64,
}

impl DomainStream {
    pub fn new(primary: &str, stickiness: f64, max_new_tokens: usize, rng: Rng) -> Self {
        let primary_static = DOMAINS
            .iter()
            .find(|d| **d == primary)
            .copied()
            .unwrap_or_else(|| panic!("unknown domain '{primary}'"));
        DomainStream {
            primary: primary_static,
            current: primary_static,
            stickiness,
            max_new_tokens,
            rng,
            seq: 0,
        }
    }

    pub fn current_domain(&self) -> &'static str {
        self.current
    }

    /// Force a domain (used by the domain-shift example to create abrupt
    /// mid-run transitions).
    pub fn set_primary(&mut self, domain: &str) {
        self.primary = DOMAINS
            .iter()
            .find(|d| **d == domain)
            .copied()
            .unwrap_or_else(|| panic!("unknown domain '{domain}'"));
    }

    /// Next request in the stream.
    pub fn next_request(&mut self) -> Request {
        self.current = if self.rng.bool(self.stickiness) {
            self.primary
        } else {
            // Jump to a uniformly random *other* domain.
            loop {
                let d = *self.rng.choose(&DOMAINS);
                if d != self.primary {
                    break d;
                }
            }
        };
        let prompt = domains::prompt(self.current, &mut self.rng);
        self.seq += 1;
        Request { prompt, domain: self.current, max_new_tokens: self.max_new_tokens, seq: self.seq }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sticky_stream_stays_mostly_primary() {
        let mut s = DomainStream::new("gsm8k", 0.9, 50, Rng::new(0));
        let mut primary_count = 0;
        let n = 1000;
        for _ in 0..n {
            if s.next_request().domain == "gsm8k" {
                primary_count += 1;
            }
        }
        let frac = primary_count as f64 / n as f64;
        assert!((frac - 0.9).abs() < 0.04, "frac {frac}");
    }

    #[test]
    fn stationary_stream_never_leaves() {
        let mut s = DomainStream::new("alpaca", 1.0, 50, Rng::new(1));
        for _ in 0..100 {
            assert_eq!(s.next_request().domain, "alpaca");
        }
    }

    #[test]
    fn requests_numbered_and_bounded() {
        let mut s = DomainStream::new("spider", 0.8, 150, Rng::new(2));
        let r1 = s.next_request();
        let r2 = s.next_request();
        assert_eq!(r1.seq, 1);
        assert_eq!(r2.seq, 2);
        assert_eq!(r1.max_new_tokens, 150);
        assert!(r1.prompt.len() < 128);
    }

    #[test]
    fn set_primary_redirects() {
        let mut s = DomainStream::new("alpaca", 1.0, 50, Rng::new(3));
        s.set_primary("hle");
        assert_eq!(s.next_request().domain, "hle");
    }

    #[test]
    #[should_panic]
    fn unknown_primary_panics() {
        DomainStream::new("nope", 0.5, 50, Rng::new(0));
    }
}
