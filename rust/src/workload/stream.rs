//! Non-stationary per-client prompt streams.
//!
//! Each draft server serves one end-user whose requests follow a Markov
//! domain process: with probability `stickiness` the next request stays in
//! the client's primary domain, otherwise it jumps to a uniformly random
//! other domain. Domain shifts change the *true* acceptance rate mid-run —
//! the non-stationarity that GoodSpeed's smoothed estimators must track
//! (paper §III-B "dynamic evolution of client prompts").

use anyhow::{anyhow, Result};

use super::domains::{self, DOMAINS};
use crate::util::Rng;

/// Resolve a domain name to its static entry — unknown names are a
/// configuration error (`Scenario::validate` reports them before any
/// stream is built), not a panic.
fn resolve_domain(name: &str) -> Result<&'static str> {
    DOMAINS
        .iter()
        .find(|d| **d == name)
        .copied()
        .ok_or_else(|| anyhow!("unknown domain '{name}' (known: {})", DOMAINS.join(", ")))
}

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub prompt: String,
    pub domain: &'static str,
    pub max_new_tokens: usize,
    /// Sequence number within the client's stream.
    pub seq: u64,
}

/// Markov-switching prompt stream for one client.
#[derive(Clone, Debug)]
pub struct DomainStream {
    primary: &'static str,
    current: &'static str,
    stickiness: f64,
    max_new_tokens: usize,
    rng: Rng,
    seq: u64,
}

impl DomainStream {
    pub fn new(primary: &str, stickiness: f64, max_new_tokens: usize, rng: Rng) -> Result<Self> {
        let primary_static = resolve_domain(primary)?;
        Ok(DomainStream {
            primary: primary_static,
            current: primary_static,
            stickiness,
            max_new_tokens,
            rng,
            seq: 0,
        })
    }

    pub fn current_domain(&self) -> &'static str {
        self.current
    }

    /// Force a domain (used by the domain-shift example to create abrupt
    /// mid-run transitions).
    pub fn set_primary(&mut self, domain: &str) -> Result<()> {
        self.primary = resolve_domain(domain)?;
        Ok(())
    }

    /// Next request in the stream.
    pub fn next_request(&mut self) -> Request {
        self.current = if self.rng.bool(self.stickiness) {
            self.primary
        } else {
            // Jump to a uniformly random *other* domain.
            loop {
                let d = *self.rng.choose(&DOMAINS);
                if d != self.primary {
                    break d;
                }
            }
        };
        let prompt = domains::prompt(self.current, &mut self.rng)
            .expect("stream domains are validated at construction");
        self.seq += 1;
        Request { prompt, domain: self.current, max_new_tokens: self.max_new_tokens, seq: self.seq }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sticky_stream_stays_mostly_primary() {
        let mut s = DomainStream::new("gsm8k", 0.9, 50, Rng::new(0)).unwrap();
        let mut primary_count = 0;
        let n = 1000;
        for _ in 0..n {
            if s.next_request().domain == "gsm8k" {
                primary_count += 1;
            }
        }
        let frac = primary_count as f64 / n as f64;
        assert!((frac - 0.9).abs() < 0.04, "frac {frac}");
    }

    #[test]
    fn stationary_stream_never_leaves() {
        let mut s = DomainStream::new("alpaca", 1.0, 50, Rng::new(1)).unwrap();
        for _ in 0..100 {
            assert_eq!(s.next_request().domain, "alpaca");
        }
    }

    #[test]
    fn requests_numbered_and_bounded() {
        let mut s = DomainStream::new("spider", 0.8, 150, Rng::new(2)).unwrap();
        let r1 = s.next_request();
        let r2 = s.next_request();
        assert_eq!(r1.seq, 1);
        assert_eq!(r2.seq, 2);
        assert_eq!(r1.max_new_tokens, 150);
        assert!(r1.prompt.len() < 128);
    }

    #[test]
    fn set_primary_redirects() {
        let mut s = DomainStream::new("alpaca", 1.0, 50, Rng::new(3)).unwrap();
        s.set_primary("hle").unwrap();
        assert_eq!(s.next_request().domain, "hle");
        assert!(s.set_primary("nope").is_err());
    }

    #[test]
    fn unknown_primary_is_an_error_not_a_panic() {
        let err = DomainStream::new("nope", 0.5, 50, Rng::new(0)).unwrap_err();
        assert!(err.to_string().contains("unknown domain 'nope'"), "{err}");
    }
}
