//! Session-API integration tests: static-membership parity with the
//! deprecated batch runner, and the churn invariants — Σ outstanding
//! allocations ≤ C across randomized join/leave schedules (sync and
//! async, M ∈ {1, 4}), and a detach never dropping or double-counting a
//! verdict.

use std::sync::Arc;

use goodspeed::configsys::{
    ChurnEvent, ChurnKind, ChurnSchedule, ClientSpec, CoordMode, Policy, Scenario,
};
use goodspeed::coordinator::{Cluster, RunOutcome, Transport};
use goodspeed::metrics::csv::write_rounds;
use goodspeed::runtime::{EngineFactory, MockEngineFactory, MockWorld};
use goodspeed::util::proptest;
use goodspeed::util::Rng;

fn factory() -> Arc<dyn EngineFactory> {
    Arc::new(MockEngineFactory::new(MockWorld {
        vocab: 32,
        max_seq: 256,
        sharpness: 3.0,
        seed: 17,
    }))
}

fn serve(s: Scenario, policy: Policy) -> RunOutcome {
    Cluster::builder(s)
        .policy(policy)
        .transport(Transport::Channel)
        .engine(factory())
        .start()
        .expect("start")
        .wait()
        .expect("run")
}

/// Static-membership parity on the builder path (the deprecated
/// `run_serving` shim — literally `builder → start → wait` — is gone):
/// independent one-shot session runs are bit-identical — same waves,
/// same RNG-determined fields, and byte-identical CSV output once the
/// wall-clock timing columns (never reproducible across runs) are
/// normalized.
#[test]
fn static_preset_runs_are_bit_identical_across_sessions() {
    let scenario = || {
        let mut s = Scenario::preset("smoke").unwrap();
        s.rounds = 20;
        s
    };
    let mut shim = serve(scenario(), Policy::GoodSpeed);
    let mut sess = serve(scenario(), Policy::GoodSpeed);
    assert!(sess.recorder.membership.is_empty(), "static runs record no epochs");
    assert_eq!(shim.recorder.rounds.len(), sess.recorder.rounds.len());
    for (a, b) in shim.recorder.rounds.iter().zip(&sess.recorder.rounds) {
        assert_eq!(a.round, b.round);
        assert_eq!(a.clients.len(), b.clients.len());
        for (ca, cb) in a.clients.iter().zip(&b.clients) {
            assert_eq!(ca.client_id, cb.client_id);
            assert_eq!(ca.s_used, cb.s_used);
            assert_eq!(ca.accepted, cb.accepted);
            assert_eq!(ca.goodput, cb.goodput);
            assert_eq!(ca.spec_depth, cb.spec_depth);
            assert_eq!(ca.next_alloc, cb.next_alloc);
            assert_eq!(ca.mean_ratio.to_bits(), cb.mean_ratio.to_bits());
            assert_eq!(ca.alpha_hat.to_bits(), cb.alpha_hat.to_bits());
            assert_eq!(ca.x_beta.to_bits(), cb.x_beta.to_bits());
        }
    }
    // Draft-side accounting identical per client.
    for (da, db) in shim.draft_stats.iter().zip(&sess.draft_stats) {
        assert_eq!(da.rounds, db.rounds);
        assert_eq!(da.tokens_drafted, db.tokens_drafted);
        assert_eq!(da.tokens_accepted, db.tokens_accepted);
        assert_eq!(da.requests_completed, db.requests_completed);
    }
    // CSV bytes (timing columns zeroed — wall clocks are not replayable).
    let zero_ns = |out: &mut RunOutcome| {
        for r in out.recorder.rounds.iter_mut() {
            r.recv_ns = 0;
            r.verify_ns = 0;
            r.send_ns = 0;
        }
    };
    zero_ns(&mut shim);
    zero_ns(&mut sess);
    let dir = std::env::temp_dir().join("goodspeed_parity_csv");
    std::fs::create_dir_all(&dir).unwrap();
    let pa = dir.join("shim.csv");
    let pb = dir.join("session.csv");
    write_rounds(&pa, &shim.recorder).unwrap();
    write_rounds(&pb, &sess.recorder).unwrap();
    assert_eq!(
        std::fs::read(&pa).unwrap(),
        std::fs::read(&pb).unwrap(),
        "CSV bytes must be identical"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Build a randomized churn scenario on the 8-client sharded preset:
/// joins and leaves at random wave boundaries, random mode, M shards.
fn random_churn_scenario(rng: &mut Rng, mode: CoordMode, m: usize) -> Scenario {
    let mut s = Scenario::preset("sharded").unwrap();
    s.num_verifiers = m;
    s.shard_rebalance_every = if rng.bool(0.5) { 8 } else { 0 };
    s.rounds = 16 + rng.below(12);
    s.coord_mode = mode;
    s.batch_window_us = 2_000;
    s.min_wave_fill = if mode == CoordMode::Async { 1 + rng.below(4) as usize } else { 0 };
    let n = s.num_clients;
    let joins = rng.below(3) as usize;
    let mut events = Vec::new();
    for _ in 0..joins {
        events.push(ChurnEvent {
            at_wave: rng.below(s.rounds),
            kind: ChurnKind::Join(ClientSpec::new("qwen-draft-06b", "alpaca")),
        });
    }
    // Leaves pick distinct ids among initial clients (joins may land
    // after the leave wave, so only residents are safe targets).
    let leaves = rng.below(3) as usize;
    let mut left: Vec<usize> = Vec::new();
    for _ in 0..leaves {
        let id = rng.below(n as u64) as usize;
        if !left.contains(&id) {
            left.push(id);
            events.push(ChurnEvent { at_wave: rng.below(s.rounds), kind: ChurnKind::Leave(id) });
        }
    }
    s.churn = ChurnSchedule { events };
    s.validate().expect("random churn scenario must validate");
    s
}

/// Walk a finished run's records + membership events and assert the
/// reservation invariant Σ outstanding grants over members ≤ C at every
/// wave boundary (single-verifier runs: the budget is one global C).
fn assert_reservation_invariant(out: &RunOutcome, s: &Scenario) {
    let n = s.num_clients;
    let slots = n + s.churn.join_count();
    let initial = (s.capacity / n.max(1)).min(s.max_draft);
    let mut outstanding = vec![0usize; slots];
    let mut member = vec![false; slots];
    for i in 0..n {
        outstanding[i] = initial;
        member[i] = true;
    }
    let mut events = out.recorder.membership.clone();
    events.sort_by_key(|e| (e.wave, e.epoch));
    let mut cursor = 0usize;
    for rec in &out.recorder.rounds {
        while cursor < events.len() && events[cursor].wave <= rec.round {
            for &(id, grant) in &events[cursor].joined {
                member[id] = true;
                outstanding[id] = grant;
            }
            for &id in &events[cursor].left {
                member[id] = false;
                outstanding[id] = 0;
            }
            cursor += 1;
        }
        let reserved: usize =
            (0..slots).filter(|&i| member[i]).map(|i| outstanding[i]).sum();
        assert!(
            reserved <= s.capacity,
            "wave {}: Σ outstanding {reserved} > C {}",
            rec.round,
            s.capacity
        );
        for c in &rec.clients {
            outstanding[c.client_id] = c.next_alloc;
        }
        let after: usize = (0..slots).filter(|&i| member[i]).map(|i| outstanding[i]).sum();
        assert!(
            after <= s.capacity,
            "wave {}: post-allocation Σ outstanding {after} > C {}",
            rec.round,
            s.capacity
        );
    }
}

/// Detach accounting: every verdict the coordinator delivered was applied
/// exactly once client-side — a drain drops the client's stale draft, but
/// never a verdict, and never double-counts one.
fn assert_verdict_accounting(out: &RunOutcome) {
    for (i, d) in out.draft_stats.iter().enumerate() {
        assert_eq!(
            d.rounds,
            out.recorder.participation()[i],
            "client {i}: verdicts delivered vs applied"
        );
        assert_eq!(
            d.tokens_accepted,
            out.recorder.cum_accepted()[i],
            "client {i}: accepted-token accounting"
        );
    }
}

#[test]
fn prop_reservation_invariant_under_random_churn_single_verifier() {
    for mode in [CoordMode::Sync, CoordMode::Async] {
        proptest::check(
            &format!("churn_invariant_m1_{}", mode.name()),
            6,
            |rng| {
                let s = random_churn_scenario(rng, mode, 1);
                let out = serve(s.clone(), Policy::GoodSpeed);
                assert_reservation_invariant(&out, &s);
                assert_verdict_accounting(&out);
                // Departed clients really retired: in sync mode every
                // scheduled Leave completes its drain within the run (in
                // async mode the budget may exhaust with a drain pending).
                let wanted: usize = s
                    .churn
                    .events
                    .iter()
                    .filter(|e| matches!(e.kind, ChurnKind::Leave(_)))
                    .count();
                let seen: usize =
                    out.recorder.membership.iter().map(|ev| ev.left.len()).sum();
                if mode == CoordMode::Sync {
                    assert_eq!(seen, wanted, "every scheduled departure must retire");
                } else {
                    assert!(seen <= wanted);
                }
            },
        );
    }
}

#[test]
fn prop_churn_on_the_sharded_pool_stays_within_budget() {
    for mode in [CoordMode::Sync, CoordMode::Async] {
        proptest::check(&format!("churn_pool_m4_{}", mode.name()), 4, |rng| {
            let s = random_churn_scenario(rng, mode, 4);
            let out = serve(s.clone(), Policy::GoodSpeed);
            assert!(out.pool.is_some(), "M=4 must run on the pool");
            // Per-wave node spend never exceeds the global budget, through
            // every membership change and rebalance.
            for r in &out.recorder.rounds {
                let used: usize = r.clients.iter().map(|c| c.s_used).sum();
                assert!(used <= s.capacity, "wave used {used} > C {}", s.capacity);
            }
            assert_verdict_accounting(&out);
            // Every departure retires at most once, and a retired session
            // had served before it left (the drain delivered its final
            // verdict rather than dropping it). Pool wave counters are
            // shard-local, so the per-wave ordering check lives in the
            // single-verifier property above.
            let mut left_ids: Vec<usize> =
                out.recorder.membership.iter().flat_map(|ev| ev.left.clone()).collect();
            let total_left = left_ids.len();
            left_ids.sort_unstable();
            left_ids.dedup();
            assert_eq!(left_ids.len(), total_left, "a session retired twice");
            for id in left_ids {
                assert!(
                    out.recorder.participation()[id] > 0,
                    "retired client {id} never served"
                );
            }
        });
    }
}

/// External churn: attach/detach through the handle, snapshot coherence,
/// and the typed error paths.
#[test]
fn external_attach_detach_lifecycle() {
    let mut s = Scenario::preset("smoke").unwrap();
    s.rounds = 4000; // long enough that control wins the race comfortably
    s.num_clients = 2;
    s.links = Scenario::default_links(2, s.seed);
    let handle = Cluster::builder(s)
        .policy(Policy::GoodSpeed)
        .transport(Transport::Channel)
        .engine(factory())
        .reserve_slots(2)
        .start()
        .unwrap();

    // Unknown domain: typed configuration error, nothing admitted.
    let err = handle.attach(ClientSpec::new("qwen-draft-06b", "nope")).unwrap_err();
    assert!(err.to_string().contains("unknown domain"), "{err}");
    // Detach of a nonexistent session: typed error.
    let err = handle.detach(7).unwrap_err();
    assert!(err.to_string().contains("not an active session"), "{err}");

    let id = handle.attach(ClientSpec::new("qwen-draft-06b", "gsm8k")).unwrap();
    assert_eq!(id, 2, "first fresh slot");
    // The snapshot publishes at the boundary right after the admission;
    // poll briefly to avoid racing it.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
    let snap = loop {
        let snap = handle.snapshot();
        if snap.members.contains(&id) {
            break snap;
        }
        assert!(std::time::Instant::now() < deadline, "admission never published");
        std::thread::sleep(std::time::Duration::from_millis(1));
    };
    assert_eq!(snap.attached_total, 3);
    assert!(snap.epoch >= 1);

    // Second attach fills the reserve; a third must fail typed.
    let id2 = handle.attach(ClientSpec::new("qwen-draft-06b", "cnn")).unwrap();
    assert_eq!(id2, 3);
    let err = handle.attach(ClientSpec::new("qwen-draft-06b", "cnn")).unwrap_err();
    assert!(err.to_string().contains("no free client slots"), "{err}");

    // Graceful drain of a resident: wait for the retirement epoch.
    handle.detach(0).unwrap();
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
    loop {
        let snap = handle.snapshot();
        if !snap.members.contains(&0) {
            assert_eq!(snap.retired_total, 1);
            break;
        }
        assert!(std::time::Instant::now() < deadline, "drain never completed");
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    // Double detach: the session is gone.
    assert!(handle.detach(0).is_err());

    let out = handle.stop().unwrap();
    // The joiners served; the drained resident kept its archived stats.
    assert!(out.recorder.participation()[id] > 0, "joiner must have served");
    assert!(out.recorder.participation()[0] > 0);
    assert_verdict_accounting(&out);
    // Membership log: 2 joins + 1 leave.
    let joins: usize = out.recorder.membership.iter().map(|e| e.joined.len()).sum();
    let leaves: usize = out.recorder.membership.iter().map(|e| e.left.len()).sum();
    assert_eq!((joins, leaves), (2, 1));
    assert_reservation_invariant_external(&out);
}

/// Same reservation walk, but with joins whose grants come from the
/// membership log (external attaches do not appear in the scenario).
fn assert_reservation_invariant_external(out: &RunOutcome) {
    // Reconstruct slot count from the recorder.
    let slots = out.recorder.n_clients();
    let mut s = Scenario::preset("smoke").unwrap();
    s.num_clients = 2;
    s.churn = ChurnSchedule::default();
    let initial = (s.capacity / 2).min(s.max_draft);
    let mut outstanding = vec![0usize; slots];
    let mut member = vec![false; slots];
    for i in 0..2 {
        outstanding[i] = initial;
        member[i] = true;
    }
    let mut events = out.recorder.membership.clone();
    events.sort_by_key(|e| (e.wave, e.epoch));
    let mut cursor = 0usize;
    for rec in &out.recorder.rounds {
        while cursor < events.len() && events[cursor].wave <= rec.round {
            for &(id, grant) in &events[cursor].joined {
                member[id] = true;
                outstanding[id] = grant;
            }
            for &id in &events[cursor].left {
                member[id] = false;
                outstanding[id] = 0;
            }
            cursor += 1;
        }
        for c in &rec.clients {
            outstanding[c.client_id] = c.next_alloc;
        }
        let after: usize = (0..slots).filter(|&i| member[i]).map(|i| outstanding[i]).sum();
        assert!(after <= s.capacity, "wave {}: Σ {after} > C {}", rec.round, s.capacity);
    }
}

/// Scheduled churn over real sockets: the hello handshake and Leave
/// frames travel the TCP wire, and the run completes cleanly.
#[test]
fn scheduled_churn_over_tcp() {
    let mut s = Scenario::preset("smoke").unwrap();
    s.rounds = 30;
    s.churn = ChurnSchedule {
        events: vec![
            ChurnEvent {
                at_wave: 8,
                kind: ChurnKind::Join(ClientSpec::new("qwen-draft-06b", "cnn")),
            },
            ChurnEvent { at_wave: 20, kind: ChurnKind::Leave(0) },
        ],
    };
    let out = Cluster::builder(s.clone())
        .policy(Policy::GoodSpeed)
        .transport(Transport::Tcp)
        .engine(factory())
        .start()
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(out.recorder.membership.len(), 2);
    assert!(out.recorder.participation()[2] > 0, "TCP joiner must serve");
    assert_reservation_invariant(&out, &s);
    assert_verdict_accounting(&out);
}

/// Live vs analytic through membership changes: the same churn schedule
/// produces the same membership epochs in both stacks, and the joiner
/// converges to a comparable relative share.
#[test]
fn live_and_analytic_agree_through_churn() {
    use goodspeed::simulate::analytic::AnalyticSim;
    let mut s = Scenario::preset("churn").unwrap();
    s.rounds = 150;
    s.churn = ChurnSchedule {
        events: vec![
            ChurnEvent {
                at_wave: 50,
                kind: ChurnKind::Join(ClientSpec::new("qwen-draft-06b", "cnn")),
            },
            ChurnEvent { at_wave: 100, kind: ChurnKind::Leave(1) },
        ],
    };
    let live = serve(s.clone(), Policy::GoodSpeed);
    let mut sim = AnalyticSim::from_scenario(&s, Policy::GoodSpeed);
    sim.run();
    // Same epochs, same member sets, at the same wave boundaries.
    assert_eq!(live.recorder.membership.len(), sim.recorder().membership.len());
    for (a, b) in live.recorder.membership.iter().zip(&sim.recorder().membership) {
        assert_eq!(a.wave, b.wave);
        assert_eq!(a.members, b.members);
        assert_eq!(a.left, b.left);
        assert_eq!(
            a.joined.iter().map(|(id, _)| *id).collect::<Vec<_>>(),
            b.joined.iter().map(|(id, _)| *id).collect::<Vec<_>>()
        );
    }
    // Relative share of the joiner vs the steady residents, live vs sim
    // (mock-engine and analytic absolute goodputs differ; the scheduler's
    // equalization makes the *shares* comparable).
    let rel = |avg: &[f64]| -> f64 {
        let residents = [0usize, 2, 3];
        let mean: f64 =
            residents.iter().map(|&i| avg[i]).sum::<f64>() / residents.len() as f64;
        avg[4] / mean.max(1e-12)
    };
    let live_rel = rel(&live.recorder.avg_goodput());
    let sim_rel = rel(&sim.recorder().avg_goodput());
    assert!(
        (live_rel - sim_rel).abs() <= 0.25 * sim_rel,
        "joiner share drifted: live {live_rel:.3} vs analytic {sim_rel:.3}"
    );
}
