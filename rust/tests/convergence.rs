//! Theory-validation integration tests: the stochastic system vs the
//! fluid limit (paper Theorems 1 & 3) and the Fig 4 convergence shape.

use goodspeed::configsys::{Policy, Scenario, Smoothing};
use goodspeed::sched::utility::LogUtility;
use goodspeed::simulate::fluid::{optimal_allocation, FluidSim};
use goodspeed::simulate::AnalyticSim;

fn stationary_scenario(clients: usize, rounds: u64) -> Scenario {
    let mut s = Scenario::preset("qwen-8c-150").unwrap();
    s.num_clients = clients;
    s.rounds = rounds;
    s.domain_stickiness = 1.0;
    s.links = Scenario::default_links(clients, s.seed);
    s
}

#[test]
fn stochastic_system_concentrates_near_fluid_optimum() {
    // Theorem 1: for small β, X^β(t) ends near x*.
    let mut s = stationary_scenario(8, 6000);
    s.beta = Smoothing::Fixed(0.02);
    s.eta = Smoothing::Fixed(0.02);
    let mut sim = AnalyticSim::from_scenario(&s, Policy::GoodSpeed);
    let alphas = sim.true_alphas();
    let (x_star, _) = optimal_allocation(&alphas, s.capacity, s.max_draft);
    sim.run();
    let dist: f64 = sim
        .estimators()
        .x_beta
        .iter()
        .zip(&x_star)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt();
    let norm: f64 = x_star.iter().map(|x| x * x).sum::<f64>().sqrt();
    assert!(
        dist / norm < 0.25,
        "‖X^β − x*‖/‖x*‖ = {:.3} (X^β = {:?}, x* = {:?})",
        dist / norm,
        sim.estimators().x_beta,
        x_star
    );
}

#[test]
fn smaller_beta_concentrates_tighter() {
    // The Theorem 1 trend itself: β ↓ ⇒ tail distance ↓ (allow slack for
    // the shared-run stochasticity; the full decay table is the
    // fluid_limit bench).
    let measure = |beta: f64| -> f64 {
        let mut s = stationary_scenario(8, 5000);
        s.beta = Smoothing::Fixed(beta);
        s.eta = Smoothing::Fixed((beta * 0.6).min(0.3));
        let mut sim = AnalyticSim::from_scenario(&s, Policy::GoodSpeed);
        let alphas = sim.true_alphas();
        let (x_star, _) = optimal_allocation(&alphas, s.capacity, s.max_draft);
        sim.run();
        let tail = &sim.recorder().rounds[3000..];
        tail.iter()
            .map(|r| {
                r.clients
                    .iter()
                    .zip(&x_star)
                    .map(|(c, &xs)| (c.x_beta - xs) * (c.x_beta - xs))
                    .sum::<f64>()
                    .sqrt()
            })
            .sum::<f64>()
            / tail.len() as f64
    };
    let d_big = measure(0.5);
    let d_small = measure(0.05);
    assert!(
        d_small < d_big * 0.7,
        "β=0.05 dist {d_small:.4} must be ≪ β=0.5 dist {d_big:.4}"
    );
}

#[test]
fn fig4_shape_exploration_then_dominance() {
    // The Fig 4 narrative on the analytic stack: GoodSpeed's U(x̄(T))
    // stabilizes and ends above both baselines.
    let run = |p: Policy| -> Vec<f64> {
        let mut s = stationary_scenario(8, 600);
        s.domain_stickiness = 0.85;
        let mut sim = AnalyticSim::from_scenario(&s, p);
        let mut curve = Vec::new();
        for _ in 0..600 {
            sim.step();
            curve.push(sim.recorder().utility_of_avg(&LogUtility));
        }
        curve
    };
    let gs = run(Policy::GoodSpeed);
    let fx = run(Policy::FixedS);
    let rd = run(Policy::RandomS);
    assert!(gs[599] > fx[599], "goodspeed {:.4} vs fixed {:.4}", gs[599], fx[599]);
    assert!(gs[599] > rd[599], "goodspeed {:.4} vs random {:.4}", gs[599], rd[599]);
    // Stabilized by ~400 (paper): the late slope must be far below the
    // early (exploration) slope — the curve flattens, qualitatively
    // matching Fig 4 (under 0.85-sticky domains the environment itself
    // keeps drifting, so an absolute threshold would be wrong).
    let early_drift = (gs[101] - gs[1]).abs() / 100.0;
    let late_drift = (gs[599] - gs[499]).abs() / 100.0;
    assert!(
        late_drift < 0.5 * early_drift,
        "late slope {late_drift:.5} vs early {early_drift:.5}"
    );
}

#[test]
fn fluid_path_attracted_from_many_starts() {
    // Theorem 3 over random heterogeneous instances.
    let mut rng = goodspeed::util::Rng::new(99);
    for _ in 0..5 {
        let n = 2 + rng.below(6) as usize;
        let alphas: Vec<f64> = (0..n).map(|_| 0.1 + 0.85 * rng.f64()).collect();
        let c = 4 + rng.below(28) as usize;
        let (x_star, u_star) = optimal_allocation(&alphas, c, 32);
        let mut sim = FluidSim::new(alphas.clone(), c, 32);
        sim.x = (0..n).map(|_| 0.05 + 5.0 * rng.f64()).collect();
        sim.run_to_fixed_point(0.02, 50_000);
        assert!(
            (sim.utility() - u_star).abs() < 0.02,
            "U(fluid end) {:.4} vs U(x*) {u_star:.4} (alphas {alphas:?}, C={c}, x={:?}, x*={x_star:?})",
            sim.utility(),
            sim.x
        );
    }
}
