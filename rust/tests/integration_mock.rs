//! Cross-module integration tests on the mock engine: full Algorithm 1
//! runs over both transports, policy comparisons, failure injection, and
//! system-level invariants that unit tests cannot see.

use std::sync::Arc;

use goodspeed::configsys::{CoordMode, Policy, Scenario, Smoothing};
use goodspeed::coordinator::{Cluster, Transport};
use goodspeed::runtime::{EngineFactory, MockEngineFactory, MockWorld};
use goodspeed::sched::utility::LogUtility;

fn factory(vocab: usize, max_seq: usize) -> Arc<dyn EngineFactory> {
    Arc::new(MockEngineFactory::new(MockWorld { vocab, max_seq, sharpness: 3.0, seed: 21 }))
}

fn scenario(clients: usize, rounds: u64, capacity: usize) -> Scenario {
    let mut s = Scenario::preset("qwen-8c-150").unwrap();
    s.num_clients = clients;
    s.rounds = rounds;
    s.capacity = capacity;
    s.links = Scenario::default_links(clients, s.seed);
    s
}

fn run(s: Scenario, policy: Policy, transport: Transport, network: bool) -> goodspeed::coordinator::RunOutcome {
    Cluster::builder(s)
        .policy(policy)
        .transport(transport)
        .simulate_network(network)
        .engine(factory(64, 256))
        .start()
        .expect("start")
        .wait()
        .expect("run")
}

#[test]
fn eight_clients_goodspeed_full_run() {
    let out = run(scenario(8, 60, 20), Policy::GoodSpeed, Transport::Channel, false);
    assert_eq!(out.summary.rounds, 60);
    // System-level conservation: total goodput == Σ (accepted + 1).
    for r in &out.recorder.rounds {
        for c in &r.clients {
            assert_eq!(c.goodput, c.accepted + 1);
            assert!(c.accepted <= c.s_used);
        }
        let used: usize = r.clients.iter().map(|c| c.s_used).sum();
        assert!(used <= 20, "capacity violated: {used}");
    }
    // Draft-side and coordinator-side accounting agree.
    for (i, d) in out.draft_stats.iter().enumerate() {
        let coord_accepted: u64 =
            out.recorder.rounds.iter().map(|r| r.clients[i].accepted as u64).sum();
        assert_eq!(d.tokens_accepted, coord_accepted, "client {i}");
    }
}

#[test]
fn goodspeed_utility_dominates_baselines_under_heterogeneity() {
    // Strong α spread via domains; GoodSpeed must win on U(x̄).
    let mut vals = Vec::new();
    for p in Policy::all() {
        let mut s = scenario(8, 250, 20);
        s.domain_stickiness = 1.0;
        let out = run(s, p, Transport::Channel, false);
        vals.push((p.name(), out.recorder.utility_of_avg(&LogUtility)));
    }
    let get = |n: &str| vals.iter().find(|(name, _)| *name == n).unwrap().1;
    assert!(
        get("goodspeed") > get("random-s"),
        "{vals:?}"
    );
    // Fixed-S is a strong baseline under symmetric caps; GoodSpeed must be
    // at least competitive (within noise) and typically above.
    assert!(get("goodspeed") > get("fixed-s") - 0.05, "{vals:?}");
}

#[test]
fn tcp_transport_with_network_sim() {
    let mut s = scenario(3, 25, 12);
    // Tighten links so the test stays fast but sleeps actually happen.
    for l in s.links.iter_mut() {
        l.latency_s = 2e-4;
        l.bandwidth_bps = 100e6;
    }
    let out = run(s, Policy::GoodSpeed, Transport::Tcp, true);
    assert_eq!(out.summary.rounds, 25);
    // Receiving time must reflect the network sleeps (≥ latency per round).
    assert!(out.summary.recv_secs > 25.0 * 2e-4);
    // Sending stays the smallest slice by far (paper: < 0.1 % of wall; on
    // this tiny 25-round run allow syscall jitter headroom).
    assert!(out.summary.send_secs < 0.05 * out.summary.wall_secs);
    assert!(out.summary.send_secs < out.summary.recv_secs);
}

#[test]
fn decaying_smoothing_schedules_run() {
    let mut s = scenario(4, 80, 16);
    s.eta = Smoothing::Decay { c: 1.0, p: 0.7 };
    s.beta = Smoothing::Decay { c: 1.0, p: 0.6 };
    let out = run(s, Policy::GoodSpeed, Transport::Channel, false);
    assert_eq!(out.summary.rounds, 80);
    // Late-round estimates must be sane probabilities.
    let last = out.recorder.rounds.last().unwrap();
    for c in &last.clients {
        assert!(c.alpha_hat > 0.0 && c.alpha_hat < 1.0);
        assert!(c.x_beta > 0.0);
    }
}

#[test]
fn tiny_context_models_complete_requests() {
    // max_seq 64 forces frequent request turnover + context clamping.
    let mut s = scenario(2, 50, 8);
    s.max_new_tokens = 10;
    let out = Cluster::builder(s)
        .policy(Policy::GoodSpeed)
        .transport(Transport::Channel)
        .engine(factory(64, 64))
        .start()
        .expect("start")
        .wait()
        .expect("run");
    let total: u64 = out.draft_stats.iter().map(|d| d.requests_completed).sum();
    assert!(total >= 4, "requests must cycle: {total}");
    // Allocation must respect the shrunken context room every round.
    for r in &out.recorder.rounds {
        for c in &r.clients {
            assert!(c.s_used <= 32);
        }
    }
}

#[test]
fn random_s_total_never_exceeds_capacity() {
    let out = run(scenario(5, 80, 13), Policy::RandomS, Transport::Channel, false);
    for r in &out.recorder.rounds {
        let used: usize = r.clients.iter().map(|c| c.s_used).sum();
        assert!(used <= 13);
    }
}

#[test]
fn alpha_estimates_separate_strong_and_weak_drafts() {
    // Clients alternate between low-noise and high-noise draft models; the
    // coordinator's α̂ must rank them correctly by the end.
    let mut s = scenario(4, 150, 16);
    s.draft_models = vec!["qwen-draft-17b".into(), "qwen-draft-06b".into()]; // noise 0.3 / 0.5
    let out = run(s, Policy::FixedS, Transport::Channel, false);
    let last = out.recorder.rounds.last().unwrap();
    let strong = (last.clients[0].alpha_hat + last.clients[2].alpha_hat) / 2.0;
    let weak = (last.clients[1].alpha_hat + last.clients[3].alpha_hat) / 2.0;
    assert!(
        strong > weak + 0.03,
        "α̂ must separate models: strong {strong:.3} weak {weak:.3}"
    );
}

fn async_scenario(clients: usize, rounds: u64, capacity: usize) -> Scenario {
    let mut s = scenario(clients, rounds, capacity);
    s.coord_mode = CoordMode::Async;
    s.batch_window_us = 300;
    s.min_wave_fill = (clients / 2).max(1);
    s
}

#[test]
fn async_mode_full_run_over_channel() {
    let clients = 4;
    let rounds = 20u64;
    let out = run(async_scenario(clients, rounds, 16), Policy::GoodSpeed, Transport::Channel, false);
    // Same total verification budget as sync (final wave may overshoot by
    // at most n−1 verdicts).
    let delivered: u64 = out.recorder.participation().iter().sum();
    let budget = rounds * clients as u64;
    assert!(delivered >= budget && delivered < budget + clients as u64, "{delivered}");
    // System-level conservation inside every wave.
    for r in &out.recorder.rounds {
        assert!(!r.clients.is_empty());
        for c in &r.clients {
            assert_eq!(c.goodput, c.accepted + 1);
            assert!(c.accepted <= c.s_used);
        }
        let used: usize = r.clients.iter().map(|c| c.s_used).sum();
        assert!(used <= 16, "capacity violated: {used}");
    }
    // Draft-side and coordinator-side accounting agree per client.
    for (i, d) in out.draft_stats.iter().enumerate() {
        assert_eq!(d.tokens_accepted, out.recorder.cum_accepted()[i], "client {i}");
    }
}

#[test]
fn async_mode_over_tcp_with_straggler_network() {
    // The headline configuration: real sockets, real link sleeps, one
    // straggler — the async pipeline must keep all clients progressing.
    // Links are pinned (not the seeded preset spread) so the fast-client
    // budget burn rate vs the straggler's first-arrival time has wide
    // margins on loaded CI machines.
    let mut s = Scenario::preset("straggler").unwrap();
    s.rounds = 12; // budget 48 verdicts
    s.coord_mode = CoordMode::Async;
    for l in s.links.iter_mut() {
        *l = goodspeed::configsys::LinkConfig {
            latency_s: 2e-3,
            bandwidth_bps: 25e6,
            jitter: 0.05,
        };
    }
    s.links[0].latency_s = 10e-3; // straggler: ~5× the fast RTT
    s.links[0].bandwidth_bps = 2.5e6;
    let out = run(s, Policy::GoodSpeed, Transport::Tcp, true);
    let part = out.recorder.participation();
    for (i, &p) in part.iter().enumerate() {
        assert!(p > 0, "client {i} starved: {part:?}");
    }
    // The fast clients must not be held to the straggler's pace: at least
    // one wave fired without client 0.
    let without_straggler = out
        .recorder
        .rounds
        .iter()
        .any(|r| r.clients.iter().all(|c| c.client_id != 0));
    assert!(without_straggler, "no wave ever excluded the straggler");
}

#[test]
fn run_is_reproducible_across_transports() {
    // Channel vs TCP must not change the *logical* outcome (same seeds,
    // same verdict stream) when the network sim is off.
    let a = run(scenario(3, 30, 12), Policy::GoodSpeed, Transport::Channel, false);
    let b = run(scenario(3, 30, 12), Policy::GoodSpeed, Transport::Tcp, false);
    for (ra, rb) in a.recorder.rounds.iter().zip(&b.recorder.rounds) {
        for (ca, cb) in ra.clients.iter().zip(&rb.clients) {
            assert_eq!(ca.goodput, cb.goodput);
            assert_eq!(ca.s_used, cb.s_used);
        }
    }
}

/// The tree acceptance criterion, live: the `tree` preset must beat the
/// same scenario on chains at the exact same node budget, and the two
/// must agree with the analytic simulator's steady state.
#[test]
fn live_tree_beats_chain_and_agrees_with_analytic() {
    use goodspeed::configsys::SpecShape;
    use goodspeed::simulate::analytic::AnalyticSim;

    let mut s = Scenario::preset("tree").unwrap();
    s.rounds = 100;
    let live_tree = run(s.clone(), Policy::GoodSpeed, Transport::Channel, false);
    let mut chain = s.clone();
    chain.spec_shape = SpecShape::Chain;
    let live_chain = run(chain.clone(), Policy::GoodSpeed, Transport::Channel, false);
    let (lt, lc) = (live_tree.recorder.goodput_per_verdict(), live_chain.recorder.goodput_per_verdict());
    assert!(lt > lc, "live tree {lt:.3} must beat live chain {lc:.3} tokens/verdict");

    // Analytic counterparts under the same shapes and budgets.
    let mut sim_tree = AnalyticSim::from_scenario(&s, Policy::GoodSpeed);
    sim_tree.run();
    let mut sim_chain = AnalyticSim::from_scenario(&chain, Policy::GoodSpeed);
    sim_chain.run();
    let (st, sc) = (sim_tree.recorder().goodput_per_verdict(), sim_chain.recorder().goodput_per_verdict());
    assert!(st > sc, "analytic tree {st:.3} must beat analytic chain {sc:.3}");

    // Live ↔ analytic steady-state agreement, world-independent form:
    // each live client's realized tokens/verdict must match the analytic
    // tree-acceptance model (`DraftTree::expected_goodput`) evaluated at
    // that client's *own* learned α̂ and mean node budget. This is the
    // cross-check that the live stack implements the model the simulator
    // integrates — `benches/tree.rs` reports the absolute figures.
    {
        use goodspeed::spec::DraftTree;
        let rec = &live_tree.recorder;
        let last = rec.rounds.last().unwrap();
        let n_clients = rec.n_clients();
        let part = rec.participation();
        for c in &last.clients {
            let i = c.client_id;
            assert!(i < n_clients && part[i] > 0);
            let mean_nodes = (rec.rounds.iter())
                .flat_map(|r| r.clients.iter())
                .filter(|x| x.client_id == i)
                .map(|x| x.s_used)
                .sum::<usize>() as f64
                / part[i] as f64;
            let shape =
                DraftTree::shaped(2, 8, mean_nodes.round() as usize, 32, usize::MAX);
            // The independent-try abstraction slightly *overestimates*
            // sibling retries (the live residual overlaps q less than the
            // target does), so the band is generous but still binding.
            let model = shape.expected_goodput(c.alpha_hat);
            let realized = rec.avg_goodput()[i];
            assert!(
                (realized - model).abs() <= 0.30 * model,
                "client {i}: realized {realized:.3} vs model {model:.3} \
                 (α̂ {:.3}, mean nodes {mean_nodes:.1})",
                c.alpha_hat
            );
        }
    }

    // Shape metrics flow to the end-of-run records: trees branched.
    let branched = live_tree
        .recorder
        .rounds
        .iter()
        .flat_map(|r| r.clients.iter())
        .any(|c| c.spec_depth < c.s_used);
    assert!(branched, "live tree mode must branch");
}
