//! Integration tests over the real AOT artifacts (trained models + PJRT).
//! Every test skips cleanly when `artifacts/manifest.json` is absent; the
//! Makefile orders `make artifacts` before `cargo test`.

use std::sync::Arc;

use goodspeed::configsys::{Policy, Scenario};
use goodspeed::coordinator::{Cluster, Transport};
use goodspeed::experiments::quickstart::run_quickstart;
use goodspeed::runtime::{default_artifacts_dir, EngineFactory, Manifest, XlaEngineFactory};

fn factory() -> Option<Arc<dyn EngineFactory>> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts missing");
        return None;
    }
    Some(Arc::new(XlaEngineFactory::new(Manifest::load(&dir).unwrap())))
}

#[test]
fn full_serving_run_on_trained_models() {
    let Some(f) = factory() else { return };
    let mut s = Scenario::preset("smoke").unwrap();
    s.rounds = 12;
    let out = Cluster::builder(s)
        .policy(Policy::GoodSpeed)
        .transport(Transport::Channel)
        .engine(f)
        .start()
        .expect("start")
        .wait()
        .expect("run");
    assert_eq!(out.summary.rounds, 12);
    assert!(out.summary.total_tokens >= 24.0); // ≥ 1 token/client/round
    // Distilled drafts must show real acceptance (α̂ well above 0.2)…
    let last = out.recorder.rounds.last().unwrap();
    for c in &last.clients {
        assert!(c.alpha_hat > 0.2, "α̂ {:.3} too low — distillation broken?", c.alpha_hat);
    }
}

#[test]
fn speculative_output_is_plausible_text() {
    // The trained target is byte-level on template text; generations must
    // stay in printable ASCII and contain spaces (word structure).
    let Some(f) = factory() else { return };
    let r = run_quickstart(
        f.as_ref(),
        "qwen",
        "qwen-draft-06b",
        "q: tom has 3 apples and buys 4 more. how many apples?",
        40,
        6,
        7,
    )
    .expect("quickstart");
    assert!(r.tokens >= 40);
    assert!(r.spec_text.contains(' '), "no word structure: {:?}", r.spec_text);
    // Acceptance must be far above the undistilled ~10 % floor.
    assert!(
        r.accepted_rate > 0.35,
        "acceptance {:.2} too low for distilled drafts",
        r.accepted_rate
    );
}

#[test]
fn speculative_round_economics_on_easy_domain() {
    // The paper-hardware speedup shape: with distilled drafts on template
    // text, each verification round must emit well over one token (μ ≫ 1)
    // and the per-token acceptance must be solidly high. (Single-stream
    // *wall-clock* speedup needs parallel verification hardware — a 1-core
    // CPU serializes the verify forward; see quickstart's report.)
    let Some(f) = factory() else { return };
    let r = run_quickstart(
        f.as_ref(),
        "qwen",
        "qwen-draft-06b",
        "### Instruction: list the garden. ### Response:",
        60,
        8,
        11,
    )
    .expect("quickstart");
    assert!(
        r.tokens_per_round > 2.0,
        "μ = {:.2} tokens/round too low (α̂ = {:.2})",
        r.tokens_per_round,
        r.alpha_hat
    );
    assert!(r.alpha_hat > 0.45, "per-token α̂ = {:.2} too low", r.alpha_hat);
    // Modeled paper-hardware speedup (Leviathan eq.) must exceed 2×.
    let modeled = goodspeed::spec::math::expected_speedup(r.alpha_hat, 8);
    assert!(modeled > 2.0, "modeled speedup {modeled:.2}");
}

#[test]
fn verify_bucket_selection_consistency() {
    // Short-prefix rounds must produce identical ratios through the s=128
    // and s=256 buckets (bucketing is a pure optimization).
    use goodspeed::runtime::{VerifyRequest};
    let Some(f) = factory() else { return };
    let mut ver = f.make_verifier("qwen").unwrap();
    let (k, v) = (f.verify_k(), f.vocab());
    let prompt = goodspeed::tokenizer::encode("act as a judge.");
    let mk = |seq: usize| {
        let mut tokens = vec![0i32; seq];
        for (i, &t) in prompt.iter().enumerate() {
            tokens[i] = t as i32;
        }
        for j in 0..4 {
            tokens[prompt.len() + j] = b'a' as i32 + j as i32;
        }
        let mut draft_tok = vec![0i32; k];
        for j in 0..4 {
            draft_tok[j] = b'a' as i32 + j as i32;
        }
        let mut q = vec![0.0f32; k * v];
        for j in 0..4 {
            for t in 0..v {
                q[j * v + t] = 1.0 / v as f32;
            }
        }
        VerifyRequest {
            tokens,
            batch: 1,
            seq,
            draft_tok,
            q_probs: q,
            pos0: vec![prompt.len() as i32],
            parent: goodspeed::runtime::chain_parent_array(1, k),
            k,
            vocab: v,
        }
    };
    let out_small = ver.verify(&mk(128)).unwrap();
    let out_big = ver.verify(&mk(256)).unwrap();
    for j in 0..4 {
        assert!(
            (out_small.ratio[j] - out_big.ratio[j]).abs() < 1e-4,
            "bucket mismatch at {j}: {} vs {}",
            out_small.ratio[j],
            out_big.ratio[j]
        );
    }
}

#[test]
fn llama_family_serves_too() {
    let Some(f) = factory() else { return };
    let mut s = Scenario::preset("llama-8c-150").unwrap();
    s.num_clients = 2;
    s.rounds = 6;
    s.capacity = 8;
    s.links = Scenario::default_links(2, s.seed);
    let out = Cluster::builder(s)
        .policy(Policy::FixedS)
        .transport(Transport::Channel)
        .engine(f)
        .start()
        .expect("start")
        .wait()
        .expect("llama run");
    assert_eq!(out.summary.rounds, 6);
}
