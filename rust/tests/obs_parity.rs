//! Observability is free: attaching the flight recorder + metrics
//! registry must not perturb a run — same RNG-determined fields, same
//! CSV bytes as the unobserved twin — while the hub faithfully mirrors
//! waves, faults, migrations, and liveness, and the serving snapshot
//! surfaces the pool-health fields.

use std::sync::Arc;
use std::time::{Duration, Instant};

use goodspeed::configsys::{Policy, Scenario};
use goodspeed::coordinator::{Cluster, RunOutcome, Transport};
use goodspeed::metrics::csv::write_rounds;
use goodspeed::obs::flight::{KIND_FAULT, KIND_WAVE};
use goodspeed::obs::{fault_code, ObsHub, ObsOptions};
use goodspeed::runtime::{EngineFactory, MockEngineFactory, MockWorld};

fn factory() -> Arc<dyn EngineFactory> {
    Arc::new(MockEngineFactory::new(MockWorld {
        vocab: 32,
        max_seq: 256,
        sharpness: 3.0,
        seed: 17,
    }))
}

fn serve(s: Scenario, observed: bool) -> (RunOutcome, Option<Arc<ObsHub>>) {
    let mut builder = Cluster::builder(s)
        .policy(Policy::GoodSpeed)
        .transport(Transport::Channel)
        .engine(factory());
    if observed {
        builder = builder.observability(ObsOptions::default());
    }
    let handle = builder.start().expect("start");
    let hub = handle.observer();
    (handle.wait().expect("run"), hub)
}

/// Assert two runs are bit-identical on every RNG-determined field and
/// byte-identical as CSV once the wall-clock columns (never replayable)
/// are zeroed — the same surface `tests/pipeline_parity.rs` pins.
fn assert_runs_identical(label: &str, mut a: RunOutcome, mut b: RunOutcome) {
    assert_eq!(a.recorder.rounds.len(), b.recorder.rounds.len(), "{label}: wave count");
    for (ra, rb) in a.recorder.rounds.iter().zip(&b.recorder.rounds) {
        assert_eq!(ra.round, rb.round, "{label}");
        assert_eq!(ra.shard, rb.shard, "{label}");
        assert_eq!(ra.clients.len(), rb.clients.len(), "{label}: wave {}", ra.round);
        for (ca, cb) in ra.clients.iter().zip(&rb.clients) {
            assert_eq!(ca.client_id, cb.client_id, "{label}: wave {}", ra.round);
            assert_eq!(ca.s_used, cb.s_used, "{label}: wave {}", ra.round);
            assert_eq!(ca.accepted, cb.accepted, "{label}: wave {}", ra.round);
            assert_eq!(ca.goodput, cb.goodput, "{label}: wave {}", ra.round);
            assert_eq!(ca.spec_depth, cb.spec_depth, "{label}: wave {}", ra.round);
            assert_eq!(ca.next_alloc, cb.next_alloc, "{label}: wave {}", ra.round);
            assert_eq!(ca.mean_ratio.to_bits(), cb.mean_ratio.to_bits(), "{label}");
            assert_eq!(ca.alpha_hat.to_bits(), cb.alpha_hat.to_bits(), "{label}");
            assert_eq!(ca.x_beta.to_bits(), cb.x_beta.to_bits(), "{label}");
        }
    }
    for (da, db) in a.draft_stats.iter().zip(&b.draft_stats) {
        assert_eq!(da.rounds, db.rounds, "{label}");
        assert_eq!(da.tokens_drafted, db.tokens_drafted, "{label}");
        assert_eq!(da.tokens_accepted, db.tokens_accepted, "{label}");
        assert_eq!(da.requests_completed, db.requests_completed, "{label}");
    }
    let zero_ns = |out: &mut RunOutcome| {
        for r in out.recorder.rounds.iter_mut() {
            r.recv_ns = 0;
            r.verify_ns = 0;
            r.send_ns = 0;
        }
    };
    zero_ns(&mut a);
    zero_ns(&mut b);
    let dir = std::env::temp_dir().join(format!("goodspeed_obsparity_{label}"));
    std::fs::create_dir_all(&dir).unwrap();
    let pa = dir.join("plain.csv");
    let pb = dir.join("observed.csv");
    write_rounds(&pa, &a.recorder).unwrap();
    write_rounds(&pb, &b.recorder).unwrap();
    assert_eq!(
        std::fs::read(&pa).unwrap(),
        std::fs::read(&pb).unwrap(),
        "{label}: CSV bytes must be identical"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Single-verifier path: an observed run is bit-identical to the
/// unobserved twin, and the hub saw every wave.
#[test]
fn observed_run_is_bit_identical_single_verifier() {
    let scenario = || {
        let mut s = Scenario::preset("smoke").unwrap();
        s.rounds = 20;
        s
    };
    let (plain, no_hub) = serve(scenario(), false);
    let (observed, hub) = serve(scenario(), true);
    assert!(no_hub.is_none(), "observability must be off by default");
    let hub = hub.expect("observed run carries a hub");
    let waves =
        hub.snapshot_events().iter().filter(|e| e.kind == KIND_WAVE).count();
    assert_eq!(waves, 20, "one wave span per wave");
    assert_eq!(hub.metrics.waves_total.get(), 20);
    assert!(hub.metrics.tokens_total.get() > 0);
    assert!(!hub.postmortem_fired(), "healthy run must not dump");
    assert_runs_identical("m1", plain, observed);
}

/// Sharded-pool path (deterministic composition: rebalancing off, full
/// fill): observed and unobserved runs stay bit-identical, with spans
/// on every shard track.
#[test]
fn observed_run_is_bit_identical_sharded_pool() {
    let scenario = || {
        let mut s = Scenario::preset("sharded").unwrap();
        s.rounds = 16;
        s.min_wave_fill = 0;
        s.batch_window_us = 20_000;
        s.shard_rebalance_every = 0;
        s.validate().expect("parity scenario must validate");
        s
    };
    let m = scenario().num_verifiers;
    let (plain, _) = serve(scenario(), false);
    let (observed, hub) = serve(scenario(), true);
    let hub = hub.expect("observed run carries a hub");
    let events = hub.snapshot_events();
    for shard in 0..m {
        assert!(
            events.iter().any(|e| e.kind == KIND_WAVE && e.shard == shard as u64),
            "shard {shard} must have wave spans"
        );
    }
    assert_runs_identical("pool", plain, observed);
}

/// Chaos pool: the hub mirrors the recorder's fault stream as instant
/// events, counts migrations, latches the postmortem, and the serving
/// snapshot surfaces per-shard liveness + migration counters mid-run.
/// The crash never recovers, so the dead-shard mask and the migration
/// counter persist to the end — the poll below cannot race the heal.
#[test]
fn chaos_pool_observability_mirrors_faults_and_liveness() {
    use goodspeed::chaos::{FaultEvent, FaultKind, FaultSchedule};
    let mut s = Scenario::preset("chaos").unwrap();
    s.chaos = FaultSchedule {
        events: vec![FaultEvent {
            at_wave: 30,
            kind: FaultKind::ShardCrash { shard: 1, recover_wave: None },
        }],
    };
    s.validate().expect("chaos scenario must validate");
    let m = s.num_verifiers;
    let handle = Cluster::builder(s)
        .policy(Policy::GoodSpeed)
        .transport(Transport::Channel)
        .engine(factory())
        .observability(ObsOptions::default())
        .start()
        .expect("start");
    let hub = handle.observer().expect("hub");
    // Poll the snapshot until the crash lands: the liveness mask shows
    // the dead shard and the migration counter moves.
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut saw_dead = false;
    let mut saw_migrations = false;
    loop {
        let snap = handle.snapshot();
        if snap.shard_live.len() == m {
            saw_dead |= snap.shard_live.iter().any(|live| !live);
            saw_migrations |= snap.migrations > 0;
        }
        if saw_dead && saw_migrations {
            break;
        }
        assert!(Instant::now() < deadline, "crash never surfaced in the snapshot");
        std::thread::sleep(Duration::from_millis(1));
    }
    let out = handle.wait().expect("run");
    let pool = out.pool.expect("chaos preset runs on the pool");
    let events = hub.snapshot_events();
    let fault_codes: Vec<u64> =
        events.iter().filter(|e| e.kind == KIND_FAULT).map(|e| e.aux).collect();
    assert!(fault_codes.contains(&fault_code("shard-crash")), "crash instant");
    assert_eq!(
        hub.metrics.faults_total.get(),
        fault_codes.len() as u64,
        "fault counter mirrors the instant stream"
    );
    assert!(
        out.recorder.faults.iter().any(|f| f.kind == "shard-crash"),
        "recorder saw the crash too"
    );
    assert!(hub.postmortem_fired(), "a firing fault latches the postmortem");
    assert_eq!(hub.metrics.migrations_total.get(), pool.migrations);
    assert!(pool.migrations > 0, "crash must migrate clients");
    for shard in 0..m {
        assert!(
            events.iter().any(|e| e.kind == KIND_WAVE && e.shard == shard as u64),
            "shard {shard} must have wave spans"
        );
    }
}

/// Single-verifier snapshots surface the degenerate pool-health shape:
/// one live shard, no migrations, no lost handoffs.
#[test]
fn single_verifier_snapshot_reports_one_live_shard() {
    let mut s = Scenario::preset("smoke").unwrap();
    s.rounds = 4000; // long enough to observe a mid-run boundary
    let handle = Cluster::builder(s)
        .policy(Policy::GoodSpeed)
        .transport(Transport::Channel)
        .engine(factory())
        .start()
        .expect("start");
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let snap = handle.snapshot();
        if snap.waves > 0 {
            assert_eq!(snap.shard_live, vec![true]);
            assert_eq!(snap.migrations, 0);
            assert_eq!(snap.handoffs_lost, 0);
            break;
        }
        assert!(Instant::now() < deadline, "no wave boundary published");
        std::thread::sleep(Duration::from_millis(1));
    }
    handle.stop().expect("stop");
}
