//! Pipelined-execution parity: `--pipelined` overlaps next-wave assembly
//! with verification on a stage thread, but every observable output must
//! stay bit-identical to the serial wave loop — same RNG-determined
//! per-client fields, same draft-side accounting, same request records,
//! and byte-identical CSVs once the (never replayable) wall-clock timing
//! columns are zeroed. The matrix covers sync/async, M ∈ {1, 4}, chain
//! and tree speculation, and trace-driven request arrivals.
//!
//! Parity configurations pin wave composition: `min_wave_fill = 0` (full
//! membership per wave) with a generous batching window, and shard
//! rebalancing off for the pool cases — wave *content* must not depend on
//! arrival timing, or serial-vs-pipelined differences in drain timing
//! would show up as (legitimate) composition drift rather than a bug.

use std::sync::Arc;

use goodspeed::configsys::{CoordMode, Policy, Scenario, SpecShape};
use goodspeed::coordinator::{Cluster, RunOutcome, Transport};
use goodspeed::metrics::csv::write_rounds;
use goodspeed::runtime::{EngineFactory, MockEngineFactory, MockWorld};
use goodspeed::util::proptest;

fn factory() -> Arc<dyn EngineFactory> {
    Arc::new(MockEngineFactory::new(MockWorld {
        vocab: 32,
        max_seq: 256,
        sharpness: 3.0,
        seed: 17,
    }))
}

fn serve(s: Scenario) -> RunOutcome {
    Cluster::builder(s)
        .policy(Policy::GoodSpeed)
        .transport(Transport::Channel)
        .engine(factory())
        .start()
        .expect("start")
        .wait()
        .expect("run")
}

/// Run `base` serially and pipelined, then assert bit-identity on every
/// deterministic output surface.
fn assert_pipelined_parity(label: &str, base: Scenario) {
    let mut serial = serve(base.clone());
    let piped = {
        let mut s = base;
        s.pipelined = true;
        s
    };
    let mut piped = serve(piped);

    assert_eq!(serial.recorder.rounds.len(), piped.recorder.rounds.len(), "{label}: wave count");
    for (a, b) in serial.recorder.rounds.iter().zip(&piped.recorder.rounds) {
        assert_eq!(a.round, b.round, "{label}");
        assert_eq!(a.shard, b.shard, "{label}");
        assert_eq!(a.clients.len(), b.clients.len(), "{label}: wave {}", a.round);
        for (ca, cb) in a.clients.iter().zip(&b.clients) {
            assert_eq!(ca.client_id, cb.client_id, "{label}: wave {}", a.round);
            assert_eq!(ca.s_used, cb.s_used, "{label}: wave {}", a.round);
            assert_eq!(ca.accepted, cb.accepted, "{label}: wave {}", a.round);
            assert_eq!(ca.goodput, cb.goodput, "{label}: wave {}", a.round);
            assert_eq!(ca.spec_depth, cb.spec_depth, "{label}: wave {}", a.round);
            assert_eq!(ca.next_alloc, cb.next_alloc, "{label}: wave {}", a.round);
            assert_eq!(ca.mean_ratio.to_bits(), cb.mean_ratio.to_bits(), "{label}");
            assert_eq!(ca.alpha_hat.to_bits(), cb.alpha_hat.to_bits(), "{label}");
            assert_eq!(ca.x_beta.to_bits(), cb.x_beta.to_bits(), "{label}");
        }
    }
    // Draft-side accounting: every client drafted and accepted the same
    // token stream (the verdict RNG draws are part of the wave discipline).
    assert_eq!(serial.draft_stats.len(), piped.draft_stats.len(), "{label}");
    for (da, db) in serial.draft_stats.iter().zip(&piped.draft_stats) {
        assert_eq!(da.rounds, db.rounds, "{label}");
        assert_eq!(da.tokens_drafted, db.tokens_drafted, "{label}");
        assert_eq!(da.tokens_accepted, db.tokens_accepted, "{label}");
        assert_eq!(da.requests_completed, db.requests_completed, "{label}");
    }
    // Trace-driven runs: per-request lifecycle records must match too.
    assert_eq!(serial.recorder.requests.len(), piped.recorder.requests.len(), "{label}");
    for (ra, rb) in serial.recorder.requests.iter().zip(&piped.recorder.requests) {
        assert_eq!(ra.client, rb.client, "{label}");
        assert_eq!(ra.arrival, rb.arrival, "{label}");
        assert_eq!(ra.first_token, rb.first_token, "{label}");
        assert_eq!(ra.completion, rb.completion, "{label}");
        assert_eq!(ra.tokens, rb.tokens, "{label}");
        assert_eq!(ra.slo_waves, rb.slo_waves, "{label}");
        assert_eq!(ra.completed, rb.completed, "{label}");
        assert_eq!(ra.met, rb.met, "{label}");
    }
    // CSV bytes (timing columns zeroed — wall clocks are not replayable,
    // and under the pipeline `verify_ns` measures overlap wall time).
    let zero_ns = |out: &mut RunOutcome| {
        for r in out.recorder.rounds.iter_mut() {
            r.recv_ns = 0;
            r.verify_ns = 0;
            r.send_ns = 0;
        }
    };
    zero_ns(&mut serial);
    zero_ns(&mut piped);
    let dir = std::env::temp_dir().join(format!("goodspeed_pipeparity_{label}"));
    std::fs::create_dir_all(&dir).unwrap();
    let pa = dir.join("serial.csv");
    let pb = dir.join("pipelined.csv");
    write_rounds(&pa, &serial.recorder).unwrap();
    write_rounds(&pb, &piped.recorder).unwrap();
    assert_eq!(
        std::fs::read(&pa).unwrap(),
        std::fs::read(&pb).unwrap(),
        "{label}: CSV bytes must be identical"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Deterministic-composition scenario for the parity matrix: full-fill
/// waves, generous window, rebalancing off.
fn parity_scenario(preset: &str, mode: CoordMode, m: usize, rounds: u64) -> Scenario {
    let mut s = Scenario::preset(preset).unwrap();
    s.rounds = rounds;
    s.coord_mode = mode;
    s.num_verifiers = m;
    s.min_wave_fill = 0;
    s.batch_window_us = 20_000;
    s.shard_rebalance_every = 0;
    s.validate().expect("parity scenario must validate");
    s
}

/// Property: serial and pipelined single-verifier runs are bit-identical
/// across random seeds, run lengths, and both speculation shapes.
#[test]
fn prop_pipelined_serial_parity_single_verifier() {
    for mode in [CoordMode::Sync, CoordMode::Async] {
        proptest::check(&format!("pipeline_parity_m1_{}", mode.name()), 4, |rng| {
            let mut s = parity_scenario("smoke", mode, 1, 12 + rng.below(10));
            s.seed = rng.next_u64();
            s.links = Scenario::default_links(s.num_clients, s.seed);
            if rng.bool(0.5) {
                s.spec_shape = SpecShape::Tree { arity: 2, depth: 4 };
            }
            s.validate().expect("randomized parity scenario must validate");
            assert_pipelined_parity(&format!("m1_{}", mode.name()), s);
        });
    }
}

#[test]
fn pipelined_parity_sharded_pool_sync() {
    assert_pipelined_parity("pool_sync", parity_scenario("sharded", CoordMode::Sync, 4, 16));
}

#[test]
fn pipelined_parity_sharded_pool_async() {
    assert_pipelined_parity("pool_async", parity_scenario("sharded", CoordMode::Async, 4, 16));
}

#[test]
fn pipelined_parity_tree_preset() {
    assert_pipelined_parity("tree", parity_scenario("tree", CoordMode::Sync, 1, 20));
}

#[test]
fn pipelined_parity_trace_requests() {
    let mut s = parity_scenario("trace", CoordMode::Sync, 1, 120);
    assert!(s.trace.is_some(), "trace preset carries arrivals");
    // Keep the preset's tighter batching window: request arrivals are
    // wave-indexed, so composition stays deterministic regardless.
    s.batch_window_us = 500;
    assert_pipelined_parity("trace", s);
}
