//! Request-level serving integration tests: the trace-driven lifecycle
//! end to end on the live cluster, the request-free parity pin, and the
//! live-vs-analytic SLO-goodput cross-check the acceptance criterion
//! asks for.

use std::sync::Arc;

use goodspeed::configsys::{ArrivalProcess, Policy, Scenario, TraceConfig};
use goodspeed::coordinator::{Cluster, RunOutcome, Transport};
use goodspeed::metrics::csv::{write_requests, write_slo_summary};
use goodspeed::runtime::{EngineFactory, MockEngineFactory, MockWorld};
use goodspeed::simulate::analytic::{run_sharded_with, AnalyticSim};

fn factory() -> Arc<dyn EngineFactory> {
    Arc::new(MockEngineFactory::new(MockWorld {
        vocab: 64,
        max_seq: 512,
        sharpness: 3.0,
        seed: 23,
    }))
}

fn serve(s: Scenario, policy: Policy) -> RunOutcome {
    Cluster::builder(s)
        .policy(policy)
        .transport(Transport::Channel)
        .engine(factory())
        .start()
        .expect("start")
        .wait()
        .expect("run")
}

#[test]
fn trace_preset_emits_request_lifecycles_end_to_end() {
    let mut s = Scenario::preset("trace").unwrap();
    s.rounds = 160;
    let out = serve(s.clone(), Policy::GoodSpeed);
    let rec = &out.recorder;
    assert!(rec.has_requests());
    assert!(!rec.requests.is_empty(), "requests must complete in 160 waves");
    // Per-request sanity: lifecycle ordering, token targets, inclusive
    // latency conventions.
    for r in &rec.requests {
        assert!(r.client < 4);
        if let Some(ft) = r.first_token {
            assert!(r.arrival <= ft && ft <= r.completion, "{r:?}");
        }
        assert!(r.ttft_waves() >= 1.0 && r.e2e_waves() >= r.ttft_waves(), "{r:?}");
        assert!(r.tpot_waves() >= 0.0);
        if r.completed {
            assert_eq!(r.tokens, 24, "{r:?}");
            assert_eq!(r.met, r.e2e_waves() <= r.slo_waves as f64, "{r:?}");
        } else {
            assert!(!r.met);
        }
    }
    // SLO-goodput is a filtered view of raw goodput: per client it never
    // exceeds the raw cumulative tokens.
    assert_eq!(rec.slo_goodput.len(), 4);
    for (i, (&slo, &raw)) in rec.slo_goodput.iter().zip(rec.cum_goodput()).enumerate() {
        assert!(slo <= raw + 1e-9, "client {i}: slo {slo} > raw {raw}");
    }
    let summary = rec.slo_summary().expect("trace run must summarize");
    assert!(summary.completed > 0);
    assert!((0.0..=1.0).contains(&summary.attainment));
    assert!(summary.ttft.0 >= 1.0 && summary.e2e.2 >= summary.e2e.0);
    // Idle masking really happened: with mean gap 28 ≫ service time,
    // some waves ran a client at a zero grant while another drafted.
    let idle_wave = rec.rounds.iter().any(|r| {
        r.clients.iter().any(|c| c.s_used == 0) && r.clients.iter().any(|c| c.s_used > 0)
    });
    assert!(idle_wave, "idle clients must be granted 0 while busy ones draft");
    // The CSV surfaces (per-request + SLO summary row) round-trip.
    let dir = std::env::temp_dir().join("goodspeed_slo_serving_test");
    std::fs::create_dir_all(&dir).unwrap();
    let rp = dir.join("requests.csv");
    let sp = dir.join("slo.csv");
    write_requests(&rp, rec).unwrap();
    write_slo_summary(&sp, rec).unwrap();
    let text = std::fs::read_to_string(&rp).unwrap();
    assert_eq!(text.lines().count(), rec.requests.len() + 1);
    let text = std::fs::read_to_string(&sp).unwrap();
    assert!(text.lines().next().unwrap().contains("ttft_p50"));
    std::fs::remove_dir_all(&dir).ok();
}

/// The acceptance pin: a request-free scenario is bit-identical to the
/// same scenario carrying an always-busy trace (one giant request per
/// client from wave 0) — the request layer is a pure accounting overlay,
/// and with nobody ever idle it cannot perturb a single allocation, RNG
/// draw, or record.
#[test]
fn request_free_runs_are_bit_identical_to_always_busy_trace() {
    let base = || {
        let mut s = Scenario::preset("smoke").unwrap();
        s.rounds = 25;
        s
    };
    let plain = serve(base(), Policy::GoodSpeed);
    let mut traced_scenario = base();
    traced_scenario.trace = Some(TraceConfig {
        // Mean gap 1e-3 waves ⇒ arrival wave 0 with overwhelming
        // probability; one request big enough to outlast the run keeps
        // every client busy from the first wave to the last.
        arrival: ArrivalProcess::Poisson { mean_gap: 1e-3 },
        slo_waves: 1_000_000,
        output_tokens: 1_000_000,
        requests_per_client: 1,
    });
    let traced = serve(traced_scenario, Policy::GoodSpeed);
    // The overlay recorded request state…
    assert!(traced.recorder.has_requests());
    assert!(plain.recorder.requests.is_empty() && plain.recorder.slo_goodput.is_empty());
    // …while the wave stream stayed bit-identical.
    assert_eq!(plain.recorder.rounds.len(), traced.recorder.rounds.len());
    for (a, b) in plain.recorder.rounds.iter().zip(&traced.recorder.rounds) {
        assert_eq!(a.round, b.round);
        assert_eq!(a.clients.len(), b.clients.len());
        for (ca, cb) in a.clients.iter().zip(&b.clients) {
            assert_eq!(ca.client_id, cb.client_id);
            assert_eq!(ca.s_used, cb.s_used);
            assert_eq!(ca.accepted, cb.accepted);
            assert_eq!(ca.goodput, cb.goodput);
            assert_eq!(ca.spec_depth, cb.spec_depth);
            assert_eq!(ca.next_alloc, cb.next_alloc);
            assert_eq!(ca.mean_ratio.to_bits(), cb.mean_ratio.to_bits());
            assert_eq!(ca.alpha_hat.to_bits(), cb.alpha_hat.to_bits());
            assert_eq!(ca.x_beta.to_bits(), cb.x_beta.to_bits());
        }
    }
    for (da, db) in plain.draft_stats.iter().zip(&traced.draft_stats) {
        assert_eq!(da.rounds, db.rounds);
        assert_eq!(da.tokens_drafted, db.tokens_drafted);
        assert_eq!(da.tokens_accepted, db.tokens_accepted);
    }
}

/// The acceptance criterion's cross-check: live and analytic SLO-goodput
/// agree when the analytic model is evaluated at each client's *observed*
/// acceptance rate (pinning removes the engine-vs-model α gap; both
/// stacks consume the identical seeded arrival schedule).
#[test]
fn live_and_analytic_slo_goodput_agree_at_observed_alpha() {
    let s = Scenario::preset("trace").unwrap();
    let live = serve(s.clone(), Policy::GoodSpeed);
    let live_rec = &live.recorder;
    let last = live_rec.rounds.last().expect("live run has waves");

    let mut sim = AnalyticSim::from_scenario(&s, Policy::GoodSpeed);
    for c in &last.clients {
        sim.pin_alpha(c.client_id, c.alpha_hat);
    }
    sim.run();
    let sim_rec = sim.recorder();

    // Both stacks consumed the same trace: identical universes.
    assert!(sim_rec.has_requests() && live_rec.has_requests());
    assert_eq!(sim_rec.slo_goodput.len(), live_rec.slo_goodput.len());
    // Per-client SLO-goodput agreement: within 40% or two requests'
    // worth of tokens, whichever is looser (completion races at the SLO
    // boundary shift whole requests between the met/missed bins).
    for i in 0..4 {
        let (a, b) = (live_rec.slo_goodput[i], sim_rec.slo_goodput[i]);
        let tol = (0.4 * a.max(b)).max(48.0);
        assert!(
            (a - b).abs() <= tol,
            "client {i}: live slo-goodput {a:.0} vs analytic {b:.0} (tol {tol:.0})"
        );
    }
    // Aggregate attainment tracks within a wide-but-binding band.
    let (ls, ss) = (live_rec.slo_summary().unwrap(), sim_rec.slo_summary().unwrap());
    assert!(
        (ls.attainment - ss.attainment).abs() <= 0.25,
        "attainment drifted: live {:.3} vs analytic {:.3}",
        ls.attainment,
        ss.attainment
    );
    assert!(ls.completed > 0 && ss.completed > 0);
}

/// The scale-out counterpart of the cross-check above: at M = 4 the live
/// pool partitions the request books across shards and merges them, the
/// analytic model runs one restricted simulator per shard — the merged
/// SLO-goodput must still agree client by client when the analytic side
/// is pinned to the live run's observed acceptance rates.
#[test]
fn sharded_live_and_analytic_slo_goodput_agree_at_m4() {
    let mut s = Scenario::preset("trace").unwrap();
    s.num_verifiers = 4;
    assert!(s.validate().is_ok(), "sharded traces are a supported pairing");
    let live = serve(s.clone(), Policy::GoodSpeed);
    let live_rec = &live.recorder;
    assert!(live_rec.has_requests());

    // Each client's last observed α̂ (waves interleave across shards, so
    // scan backwards until every client has reported).
    let mut alpha = [f64::NAN; 4];
    for r in live_rec.rounds.iter().rev() {
        for c in &r.clients {
            if alpha[c.client_id].is_nan() {
                alpha[c.client_id] = c.alpha_hat;
            }
        }
        if alpha.iter().all(|a| !a.is_nan()) {
            break;
        }
    }
    let sharded = run_sharded_with(&s, Policy::GoodSpeed, |sim| {
        for (i, &a) in alpha.iter().enumerate() {
            if !a.is_nan() {
                sim.pin_alpha(i, a);
            }
        }
    });

    let sim_slo = sharded.slo_goodput();
    assert_eq!(sim_slo.len(), live_rec.slo_goodput.len());
    for i in 0..4 {
        let (a, b) = (live_rec.slo_goodput[i], sim_slo[i]);
        let tol = (0.4 * a.max(b)).max(48.0);
        assert!(
            (a - b).abs() <= tol,
            "client {i}: live slo-goodput {a:.0} vs analytic {b:.0} (tol {tol:.0})"
        );
    }
    let ls = live_rec.slo_summary().expect("merged live summary");
    let ss = sharded.slo_summary().expect("merged analytic summary");
    assert!(
        (ls.attainment - ss.attainment).abs() <= 0.25,
        "attainment drifted: live {:.3} vs analytic {:.3}",
        ls.attainment,
        ss.attainment
    );
    assert!(ls.completed > 0 && ss.completed > 0);
}
